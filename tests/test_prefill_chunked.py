"""Chunked prefill pipelined into the hetero decode loop.

The contract under test: splitting a prompt into ``prefill_chunk``-token
chunks — executed between decode micro-batch advances, KV streamed to
the owning R-worker incrementally — must reproduce the monolithic
whole-prompt path TOKEN-EXACTLY (greedy), across storage backends,
ragged/non-divisible prompt lengths, mid-prefill migration, and the
admission/step-accounting fixes that ride along."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import random_spec, serve_trace, tiny_cfg
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


# --------------------------------------------------------------------------- #
# model-level oracle: chained chunks == whole-prompt prefill
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["granite-3-8b", "recurrentgemma-2b",
                                  "mamba2-2.7b"])
def test_model_prefill_chunk_matches_whole(arch, rng, key):
    """Chaining model.prefill_chunk over ragged prompts (chunk 4, lengths
    not divisible by it) must match whole-prompt model.prefill: same
    last-token logits AND the same decode continuation (the state —
    incl. recurrent h and frozen conv windows — is equivalent)."""
    cfg = tiny_cfg(arch)
    params = M.init_params(key, cfg)
    B, S, cache, C = 4, 13, 24, 4
    plens = np.asarray([5, 13, 3, 9], np.int32)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(plens):
        toks[i, :p] = rng.integers(1, cfg.vocab_size, p)

    ref_logits, ref_state = M.prefill(params, cfg, jnp.asarray(toks),
                                      jnp.asarray(plens), cache_len=cache)
    state = M.init_decode_state(cfg, B, cache)
    last = np.zeros((B, cfg.vocab_size), np.float32)
    for j in range(0, S, C):
        pos = np.full((B, C), -1, np.int32)
        tk = np.zeros((B, C), np.int32)
        for i, p in enumerate(plens):
            cnt = max(0, min(C, int(p) - j))
            pos[i, :cnt] = j + np.arange(cnt)
            tk[i, :cnt] = toks[i, j:j + cnt]
        lg, state = M.prefill_chunk(params, cfg, state, jnp.asarray(tk),
                                    jnp.asarray(pos))
        lg = np.asarray(lg)
        for i, p in enumerate(plens):
            if j < p <= j + C:
                last[i] = lg[i]
    assert np.abs(last - np.asarray(ref_logits)).max() < 2e-4
    assert np.array_equal(np.asarray(state["lengths"]), plens)
    # decode continuation: 3 greedy steps from both states
    tok = np.asarray(ref_logits).argmax(-1).astype(np.int32)
    st_r, st_c = ref_state, state
    for _ in range(3):
        lr, st_r = M.decode_step(params, cfg, st_r, jnp.asarray(tok[:, None]))
        lc, st_c = M.decode_step(params, cfg, st_c, jnp.asarray(tok[:, None]))
        assert float(jnp.abs(lr - lc).max()) < 2e-4
        tok = np.asarray(lr).argmax(-1).astype(np.int32)


# --------------------------------------------------------------------------- #
# serving-level token-exact equivalence.  The full storage x schedule x
# chunked/monolithic (x shared-prefix) matrix lives in
# tests/test_equiv_matrix.py on the conftest serve_trace harness; this
# module keeps only the chunk-specific scenarios the matrix can't cover
# (recurrent archs, skew/jitter, mid-prefill migration, regressions).
# --------------------------------------------------------------------------- #
_serve_trace = serve_trace          # local aliases for the shared harness
_random_spec = random_spec


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-2b"])
def test_serving_chunked_recurrent_archs(arch, rng, key):
    """Recurrent R-state (SSD h, RG-LRU h + conv windows) must stream
    through chunked prefill too: rows decode while micro-batch mates are
    still prefilling, and the recurrences must stay untouched by either
    the decode feed (active mask) or chunk padding (identity steps)."""
    cfg = tiny_cfg(arch)
    params = M.init_params(key, cfg)
    spec = _random_spec(rng, cfg, 5)
    ref = _serve_trace(params, cfg, spec, backend="colocated")
    got = _serve_trace(params, cfg, spec, backend="hetero",
                       num_r_workers=2, prefill_chunk=4)
    assert got == ref and len(got) == len(spec)


def test_chunked_prefill_under_skew_and_jitter(rng, key):
    """Chunk completions racing decode completions out of issue order
    (slow worker + async delivery) must not change tokens."""
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    spec = _random_spec(rng, cfg, 5)
    ref = _serve_trace(params, cfg, spec, backend="colocated")

    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2, prefill_chunk=5)
    for i, w in enumerate(eng.engine.workers):
        w.slowdown = 1.0 + i
        w.sim_deliver_jitter = 1e-3
    try:
        qi = 0
        order = sorted(range(len(spec)), key=lambda i: spec[i][2])
        while (qi < len(order) or eng.queue
               or any(s is not None for s in eng.slots)) \
                and eng.step_idx < 400:
            while qi < len(order) and spec[order[qi]][2] <= eng.step_idx:
                i = order[qi]
                eng.submit(Request(rid=i, prompt=spec[i][0],
                                   max_new_tokens=spec[i][1]))
                qi += 1
            eng.step()
        got = {r.rid: list(r.generated) for r in eng.finished}
    finally:
        eng.close()
    assert got == ref


def test_chunked_prefill_survives_migration(rng, key):
    """fleet primitive mid-prefill: apply_partition between steps while
    prompts are half-streamed must export/re-install the partial rows
    (dense wire with partial positions) and keep tokens identical."""
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    spec = [(rng.integers(1, cfg.vocab_size, 11).astype(np.int32), 5, 0),
            (rng.integers(1, cfg.vocab_size, 13).astype(np.int32), 5, 0),
            (rng.integers(1, cfg.vocab_size, 9).astype(np.int32), 5, 1),
            (rng.integers(1, cfg.vocab_size, 7).astype(np.int32), 5, 2)]
    ref = _serve_trace(params, cfg, spec, backend="colocated")

    eng = ServingEngine(params, cfg, batch=8, cache_len=48,
                        backend="hetero", num_r_workers=2,
                        num_microbatches=2, prefill_chunk=3)
    try:
        qi = 0
        order = sorted(range(len(spec)), key=lambda i: spec[i][2])
        migrated = 0
        while (qi < len(order) or eng.queue
               or any(s is not None for s in eng.slots)) \
                and eng.step_idx < 400:
            while qi < len(order) and spec[order[qi]][2] <= eng.step_idx:
                i = order[qi]
                eng.submit(Request(rid=i, prompt=spec[i][0],
                                   max_new_tokens=spec[i][1]))
                qi += 1
            eng.step()
            # migrate twice, mid-prefill (prompts need >= 3 chunks)
            if eng.step_idx in (2, 4):
                new = [(0, 3), (3, 4)] if migrated % 2 == 0 \
                    else [(0, 2), (2, 4)]
                moved = eng.engine.apply_partition(new)
                assert moved > 0
                migrated += 1
        assert migrated == 2
        got = {r.rid: list(r.generated) for r in eng.finished}
    finally:
        eng.close()
    assert got == ref


# --------------------------------------------------------------------------- #
# satellite regressions
# --------------------------------------------------------------------------- #
def test_run_max_steps_is_relative(rng, key):
    """run(max_steps) used to compare against the ABSOLUTE step counter:
    a second run() on the same engine got fewer (or zero) steps."""
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=32, vocab=64)
    params = M.init_params(key, cfg)
    eng = ServingEngine(params, cfg, batch=2, cache_len=64)
    eng.submit(Request(rid=0, prompt=np.asarray([3, 4, 5], np.int32),
                       max_new_tokens=30))
    eng.run(max_steps=5)
    assert eng.step_idx == 5                 # budget consumed, not done
    eng.run(max_steps=5)                     # second call gets 5 MORE
    assert eng.step_idx == 10
    eng.submit(Request(rid=1, prompt=np.asarray([6, 7], np.int32),
                       max_new_tokens=2))
    done = eng.run(max_steps=200)            # and a full fresh budget
    assert {r.rid for r in done} == {0, 1}


def test_step_record_wall_split(rng, key):
    """StepRecord separates prefill/decode/fleet time; the legacy .wall
    stays as their sum so existing consumers keep working."""
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=32, vocab=64)
    params = M.init_params(key, cfg)
    eng = ServingEngine(params, cfg, batch=2, cache_len=32)
    eng.submit(Request(rid=0, prompt=np.asarray([3, 4, 5], np.int32),
                       max_new_tokens=3))
    eng.run(max_steps=50)
    admit = [r for r in eng.records if r.admitted]
    assert admit and admit[0].prefill_wall > 0.0
    for r in eng.records:
        assert r.decode_wall > 0.0
        assert abs(r.wall - (r.prefill_wall + r.decode_wall
                             + r.fleet_wall)) < 1e-12


def test_prefill_fn_cache_is_bounded(rng, key):
    """_prefill_cache must not grow one jitted fn per n_pad forever."""
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=32, vocab=64)
    params = M.init_params(key, cfg)
    eng = ServingEngine(params, cfg, batch=2, cache_len=32)
    for n_pad in (1, 2, 4, 8, 16, 32, 64):
        eng._prefill_fn(n_pad)
    assert len(eng._prefill_cache) <= eng._PREFILL_FN_KEEP
    # most-recently-used entries survive
    assert 64 in eng._prefill_cache and 1 not in eng._prefill_cache


def test_released_paged_row_frees_pages_and_stays_clean(rng, key):
    """A finished paged-hetero row is released but stays in the
    full-batch decode feed until its slot is reused: the RWorker must
    drop its decode writes (no write may land in freed pages), the page
    accounting must track live rows exactly, and the survivors must be
    BIT-EXACT vs serving each alone."""
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9, 7)]
    # solo oracles
    solo = []
    for i, p in enumerate(prompts):
        mnt = 2 if i == 0 else 10
        solo.append(_serve_trace(params, cfg, [(p, mnt, 0)],
                                 backend="colocated")[0])

    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", paged_kv=True, page_size=4,
                        num_r_workers=2)
    try:
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=2 if i == 0 else 10))
        # run until the short request releases its row
        while not eng.finished and eng.step_idx < 100:
            eng.step()
        assert eng.finished and eng.finished[0].rid == 0
        row = eng.finished[0].slot
        w, mb, local = eng.engine.worker_for(row)
        alloc = w.allocators[mb]
        assert not alloc.active[local] and (alloc.tables[local] == -1).all()

        def pool_accounting_exact():
            for wk in eng.engine.workers:
                for m, al in wk.allocators.items():
                    live = sum(-(-int(al.lengths[r]) // al.page)
                               for r in range(al.rows) if al.active[r])
                    assert al.used_pages() == live

        # find a step window where the free set is static, and assert
        # freed pages' contents stay bit-identical across the decode step
        clean_checked = False
        for _ in range(12):
            if all(s is None for s in eng.slots):
                break
            frees = {(id(wk), m): sorted(wk.allocators[m].free)
                     for wk in eng.engine.workers
                     for m in wk.allocators}
            snaps = {}
            for wk in eng.engine.workers:
                for lk in sorted(wk.paged_keys):
                    m = lk // cfg.num_layers
                    ids = np.asarray(sorted(wk.allocators[m].free))
                    if len(ids):
                        snaps[(id(wk), lk)] = {
                            k: np.array(v)[ids]
                            for k, v in wk.state[lk].items()}
            eng.step()
            pool_accounting_exact()
            for wk in eng.engine.workers:
                for lk in sorted(wk.paged_keys):
                    m = lk // cfg.num_layers
                    if sorted(wk.allocators[m].free) != frees[(id(wk), m)]:
                        continue          # pages were handed out: skip
                    ids = np.asarray(sorted(wk.allocators[m].free))
                    if not len(ids):
                        continue
                    for k, v in wk.state[lk].items():
                        assert np.array_equal(np.array(v)[ids],
                                              snaps[(id(wk), lk)][k]), \
                            f"decode write landed in freed page ({k})"
                    clean_checked = True
        assert clean_checked, "no static-free-set window observed"
        eng.run(max_steps=300)
        got = {r.rid: list(r.generated) for r in eng.finished}
    finally:
        eng.close()
    assert len(got) == 3
    for i in range(3):
        assert got[i] == solo[i], f"survivor rid={i} diverged"
    # every page returned once drained
    assert eng.paged_resident_bytes() == 0.0


def test_loadctl_bounds_resident_with_chunked_prefill(rng, key):
    """Algorithm 1 under chunked prefill: the controller must track an
    admission at its TRUE generation span (shifted by the prefill
    delay), or it retires the micro-batch ceil(prompt/C) steps early and
    over-admits while the old rows are still fully resident."""
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    w_lim = 70
    eng = ServingEngine(params, cfg, batch=8, cache_len=48,
                        backend="hetero", num_r_workers=2,
                        admission="loadctl", target_len=8, interval=2,
                        w_lim=w_lim, prefill_chunk=4)
    try:
        for i in range(16):
            plen = int(rng.integers(6, 15))
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    plen).astype(np.int32),
                max_new_tokens=6))
        eng.run(max_steps=500)
        assert len(eng.finished) == 16
        peak = max(rec.resident_len for rec in eng.records)
        assert peak <= w_lim + 16   # slack: ragged prompts vs S estimate
    finally:
        eng.close()
