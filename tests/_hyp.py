"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  When it
is absent the property tests must still *collect* — a missing fuzzer must
not take the deterministic tests in the same module down with it.  Import
``given/settings/st`` from here instead of from hypothesis: with
hypothesis installed they are the real thing; without it, ``@given``
replaces the test with a skip and ``st``/``settings`` become inert stubs
so module-level strategy expressions still evaluate.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy-building call chain at collection time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
