"""Training substrate: optimizer behavior, loss decrease, checkpointing,
remat equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import model as M
from repro.training import checkpoint as CK
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import adamw, cosine_warmup
from repro.training.train import loss_fn, make_train_step


def test_loss_decreases(key):
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=64, vocab=128)
    params = M.init_params(key, cfg)
    data = SyntheticLM(DataConfig(128, 32, 8, seed=0)).batches()
    init_state, train_step = make_train_step(cfg, peak_lr=5e-3, warmup=10,
                                             total_steps=300, q_chunk=8,
                                             kv_chunk=8)
    state = init_state(params)
    step = jax.jit(train_step)
    losses = []
    for _ in range(100):
        b = next(data)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15


def test_remat_matches_no_remat(key, rng):
    cfg = tiny_cfg("granite-3-8b", layers=3, d_model=64)
    params = M.init_params(key, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
             "mask": jnp.ones((2, 16), jnp.float32)}
    (l1, _), g1 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, q_chunk=8, kv_chunk=8, remat=False)
    (l2, _), g2 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, q_chunk=8, kv_chunk=8, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_adamw_grad_clip():
    init, update = adamw(1e-2, grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((4, 4))}
    st = init(p)
    g = {"w": jnp.full((4, 4), 100.0)}
    newp, st, gnorm = update(g, st, p)
    assert float(gnorm) == pytest.approx(400.0)
    # effective step bounded by lr after clipping+normalization
    assert float(jnp.abs(newp["w"] - p["w"]).max()) < 0.05


def test_cosine_warmup_shape():
    fn = cosine_warmup(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, rel=0.05)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, rel=0.05)


def test_checkpoint_roundtrip(key, tmp_path):
    cfg = tiny_cfg("grok-1-314b")   # nested stacks + moe params
    params = M.init_params(key, cfg)
    path = str(tmp_path / "ck.npz")
    CK.save(path, params)
    p2 = CK.load(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(key, tmp_path):
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    path = str(tmp_path / "ck.npz")
    CK.save(path, params)
    cfg2 = tiny_cfg("granite-3-8b", d_model=128)
    params2 = M.init_params(key, cfg2)
    with pytest.raises((ValueError, KeyError)):
        CK.load(path, params2)


def test_synthetic_data_deterministic():
    a = next(SyntheticLM(DataConfig(64, 16, 2, seed=7)).batches())
    b = next(SyntheticLM(DataConfig(64, 16, 2, seed=7)).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])
