"""Paged R-worker KV end-to-end: the paged pipeline must match the dense
pipeline and the colocated oracle to fp tolerance on ragged batches, the
paged kernel must match its jnp reference, and the serving engine must
return every page when sequences finish."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.hetero import ColocatedEngine, HeteroPipelineEngine
from repro.kernels import ops
from repro.kernels import ref as KR
from repro.models import model as M

B, S, GEN = 4, 12, 5
RAGGED = (5, 12, 3, 9)


def _engines_logits(params, cfg, tokens, plens, gen, **hetero_kw):
    eng = HeteroPipelineEngine(params, cfg, batch=B, cache_len=S + gen,
                               num_r_workers=2, num_microbatches=2,
                               kv_chunk=8, **hetero_kw)
    h = B // 2
    eng.load_prefill(0, tokens[:h, :S], plens[:h])
    eng.load_prefill(1, tokens[h:, :S], plens[h:])
    logs = []
    try:
        for t in range(gen):
            tok = tokens[:, S + t:S + t + 1]
            logs.append(jnp.concatenate(eng.decode_step([tok[:h], tok[h:]]),
                                        0))
    finally:
        eng.close()
    return jnp.stack(logs)


@pytest.mark.parametrize("page", [3, 4, 16])
def test_paged_matches_dense_and_colocated_ragged(page, rng, key):
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + GEN)))
    plens = jnp.asarray(RAGGED, jnp.int32)

    ref = ColocatedEngine(params, cfg, batch=B, cache_len=S + GEN)
    ref.load_prefill(tokens[:, :S], plens)
    ref_logits = jnp.stack([ref.decode_step(tokens[:, S + t:S + t + 1])
                            for t in range(GEN)])

    dense = _engines_logits(params, cfg, tokens, plens, GEN)
    paged = _engines_logits(params, cfg, tokens, plens, GEN,
                            paged_kv=True, page_size=page)
    assert float(jnp.abs(paged - dense).max()) < 2e-4
    assert float(jnp.abs(paged - ref_logits).max()) < 2e-4


# NOTE: the former test_paged_int8_matches_dense_int8 (§5.2 composition:
# int8 page pools == int8 dense slabs) is subsumed by the consolidated
# serving matrix — tests/test_equiv_matrix.py runs the "int8" and
# "paged-int8" storages against the same colocated oracle, so a paged
# int8 divergence from dense int8 fails there token-exactly.


def test_paged_windowed_arch_falls_back_to_dense(rng, key):
    """Windowed attention stores a rotated ring the paged layout can't
    represent — paged_kv must fall back to the dense slab and stay
    exactly equivalent (the silent-corruption case a contiguous-prefix
    conversion would hit)."""
    cfg = tiny_cfg("recurrentgemma-2b")
    assert cfg.window > 0
    params = M.init_params(key, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 3)))
    plens = jnp.asarray(RAGGED, jnp.int32)
    dense = _engines_logits(params, cfg, tokens, plens, 3)
    paged = _engines_logits(params, cfg, tokens, plens, 3,
                            paged_kv=True, page_size=4)
    assert float(jnp.abs(paged - dense).max()) < 1e-5
    # and really dense underneath: no paged layers were created
    eng = HeteroPipelineEngine(params, cfg, batch=B, cache_len=S + 3,
                               num_r_workers=1, paged_kv=True, page_size=4)
    try:
        eng.load_prefill(0, tokens[:2, :S], plens[:2])
        eng.load_prefill(1, tokens[2:, :S], plens[2:])
        assert all(not w.paged_keys for w in eng.workers)
    finally:
        eng.close()


def test_paged_noop_for_non_attention_arch(rng, key):
    """paged_kv on an arch whose R-state is not a KV slab (whisper's
    DEC_XATTN keeps the dense slab) must stay equivalent."""
    cfg = tiny_cfg("whisper-medium")
    params = M.init_params(key, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 2)))
    enc = jnp.asarray(rng.standard_normal(
        (B, cfg.encoder_seq, cfg.encoder_d_model)), jnp.float32)
    plens = jnp.full((B,), S, jnp.int32)

    outs = []
    for paged in (False, True):
        eng = HeteroPipelineEngine(params, cfg, batch=B, cache_len=S + 2,
                                   num_r_workers=2, num_microbatches=2,
                                   kv_chunk=8, paged_kv=paged)
        h = B // 2
        eng.load_prefill(0, tokens[:h, :S], plens[:h], enc_feats=enc[:h])
        eng.load_prefill(1, tokens[h:, :S], plens[h:], enc_feats=enc[h:])
        try:
            tok = tokens[:, S:S + 1]
            outs.append(jnp.concatenate(
                eng.decode_step([tok[:h], tok[h:]]), 0))
        finally:
            eng.close()
    assert float(jnp.abs(outs[0] - outs[1]).max()) < 1e-5


# ---------------------------------------------------------------------------
# kernel-level: Pallas paged flash-decode vs jnp reference
# ---------------------------------------------------------------------------
def _random_tables(rng, b, mp, page, lengths, num_pages):
    tables = np.full((b, mp), -1, np.int32)
    perm = list(rng.permutation(num_pages))
    for row in range(b):
        for k in range(-(-int(lengths[row] + 1) // page)):
            tables[row, k] = perm.pop()
    return jnp.asarray(tables)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (5, 0.0), (0, 30.0)])
def test_paged_kernel_matches_ref(window, softcap, rng):
    b, hkv, g, dh, page, mp = 3, 2, 3, 8, 4, 5
    num_pages = b * mp
    lengths = jnp.asarray([0, 7, 13], jnp.int32)
    pk = jnp.asarray(rng.standard_normal((num_pages, page, hkv, dh)),
                     jnp.float32)
    pv = jnp.asarray(rng.standard_normal((num_pages, page, hkv, dh)),
                     jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, hkv * g, dh)), jnp.float32)
    tables = _random_tables(rng, b, mp, page, np.asarray(lengths), num_pages)

    o_ref = KR.paged_decode_attention_ref(q, pk, pv, tables, lengths,
                                          window=window, softcap=softcap)
    o_pal = ops.paged_decode_attention(q, pk, pv, tables, lengths,
                                       window=window, softcap=softcap,
                                       use_kernel="pallas")
    np.testing.assert_allclose(o_pal, o_ref, atol=2e-6)


def test_paged_kernel_unmapped_row_is_zero(rng):
    """A fully released row (all-unmapped table) must output zeros, not
    stale pool data."""
    b, hkv, g, dh, page, mp = 2, 1, 2, 8, 4, 3
    pk = jnp.asarray(rng.standard_normal((6, page, hkv, dh)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((6, page, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, hkv * g, dh)), jnp.float32)
    tables = jnp.asarray([[0, 1, -1], [-1, -1, -1]], jnp.int32)
    lengths = jnp.asarray([6, 99], jnp.int32)
    for use in ("ref", "pallas"):
        o = ops.paged_decode_attention(q, pk, pv, tables, lengths,
                                       use_kernel=use)
        assert float(jnp.abs(o[1]).max()) == 0.0
        assert float(jnp.abs(o[0]).max()) > 0.0


def test_allocator_freezes_degraded_row():
    """A row whose decode-time grow hit pool exhaustion must never regrow
    (a later regrow would map freed pages over positions whose writes
    were dropped, exposing another sequence's stale KV)."""
    from repro.serving.paged_cache import PagedAllocator
    a = PagedAllocator(rows=2, num_pages=2, page=4, max_pages_per_seq=4)
    a.admit(0, 4)
    a.admit(1, 4)                            # pool now empty
    a.ensure_lengths(np.asarray([5, 4]))     # row 0 grow fails -> frozen
    assert bool(a.frozen[0])
    before = a.tables[0].copy()
    a.release(1)                             # a page becomes free
    a.ensure_lengths(np.asarray([8, 0]))     # must NOT regrow row 0
    assert np.array_equal(before, a.tables[0])
    a.admit(0, 6)                            # re-admission unfreezes
    assert not bool(a.frozen[0]) and int((a.tables[0] >= 0).sum()) == 2


# ---------------------------------------------------------------------------
# serving: admission allocates by prompt length, completion frees
# ---------------------------------------------------------------------------
def test_serving_paged_allocates_and_frees(rng, key):
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request

    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", paged_kv=True, page_size=4,
                        num_r_workers=2)
    try:
        for i in range(6):
            plen = int(rng.integers(3, 14))
            prompt = np.asarray(rng.integers(1, cfg.vocab_size, (plen,)),
                                np.int32)
            eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=5))
        peak = 0.0
        while (eng.queue or any(r is not None for r in eng.slots)) \
                and eng.step_idx < 200:
            eng.step()
            peak = max(peak, eng.paged_resident_bytes())
        assert len(eng.finished) == 6
        assert peak > 0.0
        # every page returned once the pool drained
        assert eng.paged_resident_bytes() == 0.0
        # resident pages never exceeded what the ragged lengths need:
        # far below the dense slab's batch*cache_len footprint
        from repro.serving.kv_cache import kv_bytes_per_seq
        dense = 4 * kv_bytes_per_seq(cfg, 48)
        assert peak < 0.75 * dense
    finally:
        eng.close()


def test_serving_paged_ooo_skew_frees_all_pages(rng, key):
    """Continuous batching on the event-driven loop with skewed, jittery
    workers: completions arrive out of issue order across micro-batches,
    yet the page accounting must stay exact — every page returned when
    the pool drains, every request finished."""
    from repro.serving.engine import ServingEngine
    from repro.serving.request import Request

    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(key, cfg)
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", paged_kv=True, page_size=4,
                        num_r_workers=2, schedule="ooo")
    for i, w in enumerate(eng.engine.workers):
        w.slowdown = 1.0 + i            # worker 1 twice as slow
        w.sim_deliver_jitter = 1e-3
    try:
        for i in range(5):
            plen = int(rng.integers(3, 14))
            prompt = np.asarray(rng.integers(1, cfg.vocab_size, (plen,)),
                                np.int32)
            eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=4))
        eng.run(max_steps=200)
        assert len(eng.finished) == 5
        assert eng.paged_resident_bytes() == 0.0
        stats = eng.hotpath_stats()
        assert stats.get("steps", 0) > 0 and stats.get("r_wait_s", 0) > 0
    finally:
        eng.close()
