"""Pallas kernel validation (interpret mode on CPU) against the pure-jnp
oracle, swept over shapes, dtypes, GQA ratios, and masking features —
as required for every kernel in kernels/."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R


def _mk(rng, *shape, d=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), d)


SHAPES = [
    # B, S, Hq, Hkv, D, block_s
    (2, 37, 4, 2, 16, 16),
    (3, 300, 8, 8, 32, 128),
    (2, 64, 4, 1, 128, 32),
    (1, 17, 2, 2, 64, 32),
]
FEATS = [dict(), dict(window=20), dict(window=20, sink=3), dict(softcap=8.0)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kw", FEATS)
def test_decode_attention_kernel_vs_oracle(shape, kw, rng):
    B, S, Hq, Hkv, D, bs = shape
    q, k, v = _mk(rng, B, Hq, D), _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = pos.at[0, S // 2:].set(-1)
    lengths = jnp.asarray(rng.integers(1, S, B), jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos, lengths, use_kernel="pallas",
                              block_s=bs, **kw)
    o2 = R.decode_attention_ref(q, k, v, pos, lengths, **kw)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_dtypes(dtype, rng):
    """Mixed precision: low-precision storage, fp32 accumulation (§5.1)."""
    B, S, Hq, Hkv, D = 2, 100, 8, 4, 64
    q = _mk(rng, B, Hq, D, d=dtype)
    k, v = _mk(rng, B, S, Hkv, D, d=dtype), _mk(rng, B, S, Hkv, D, d=dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([50, 99], jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos, lengths, use_kernel="pallas",
                              block_s=32)
    o2 = R.decode_attention_ref(q, k, v, pos, lengths)
    assert o1.dtype == dtype
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-2)


def test_int8_kernel_vs_oracle(rng):
    B, S, Hq, Hkv, D = 2, 100, 8, 4, 64
    q = _mk(rng, B, Hq, D)
    k, v = _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    kq, ks = ops.quantize_kv(k)
    vq, vs = ops.quantize_kv(v)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([50, 99], jnp.int32)
    o1 = ops.decode_attention_int8(q, kq, ks, vq, vs, pos, lengths,
                                   use_kernel="pallas", block_s=32)
    o2 = R.decode_attention_int8_ref(q, kq, ks, vq, vs, pos, lengths)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_int8_quantization_error_bounded(rng):
    """§5.2: int8-KV attention must stay close to the fp32 result."""
    B, S, Hq, Hkv, D = 2, 64, 4, 4, 32
    q = _mk(rng, B, Hq, D)
    k, v = _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    kq, ks = ops.quantize_kv(k)
    vq, vs = ops.quantize_kv(v)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.full((B,), S - 1, jnp.int32)
    o_q = R.decode_attention_int8_ref(q, kq, ks, vq, vs, pos, lengths)
    o_f = R.decode_attention_ref(q, k, v, pos, lengths)
    # symmetric per-vector int8: relative error ~1/127
    assert float(jnp.abs(o_q - o_f).max()) < 0.05


def test_quantize_roundtrip(rng):
    x = _mk(rng, 4, 7, 16)
    q, s = ops.quantize_kv(x)
    x2 = ops.dequantize_kv(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(x2, x, atol=float(jnp.abs(x).max()) / 100)


def test_kernel_matches_model_decode_attention(rng, key):
    """kernel == layers.flash_attention == what the model executes."""
    from repro.models import layers as L
    B, S, Hq, Hkv, D = 2, 40, 4, 2, 32
    q, k, v = _mk(rng, B, Hq, D), _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([20, 39], jnp.int32)
    o_kernel = ops.decode_attention(q, k, v, pos, lengths,
                                    use_kernel="pallas", block_s=16)
    o_model = L.flash_attention(q[:, None], k, v, lengths[:, None], pos,
                                causal=True, kv_chunk=64)[:, 0]
    np.testing.assert_allclose(o_kernel, o_model, atol=3e-5)
