"""Pallas kernel validation (interpret mode on CPU) against the pure-jnp
oracle, swept over shapes, dtypes, GQA ratios, and masking features —
as required for every kernel in kernels/."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R


def _mk(rng, *shape, d=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), d)


SHAPES = [
    # B, S, Hq, Hkv, D, block_s
    (2, 37, 4, 2, 16, 16),
    (3, 300, 8, 8, 32, 128),
    (2, 64, 4, 1, 128, 32),
    (1, 17, 2, 2, 64, 32),
]
FEATS = [dict(), dict(window=20), dict(window=20, sink=3), dict(softcap=8.0)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kw", FEATS)
def test_decode_attention_kernel_vs_oracle(shape, kw, rng):
    B, S, Hq, Hkv, D, bs = shape
    q, k, v = _mk(rng, B, Hq, D), _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = pos.at[0, S // 2:].set(-1)
    lengths = jnp.asarray(rng.integers(1, S, B), jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos, lengths, use_kernel="pallas",
                              block_s=bs, **kw)
    o2 = R.decode_attention_ref(q, k, v, pos, lengths, **kw)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_dtypes(dtype, rng):
    """Mixed precision: low-precision storage, fp32 accumulation (§5.1)."""
    B, S, Hq, Hkv, D = 2, 100, 8, 4, 64
    q = _mk(rng, B, Hq, D, d=dtype)
    k, v = _mk(rng, B, S, Hkv, D, d=dtype), _mk(rng, B, S, Hkv, D, d=dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([50, 99], jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos, lengths, use_kernel="pallas",
                              block_s=32)
    o2 = R.decode_attention_ref(q, k, v, pos, lengths)
    assert o1.dtype == dtype
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-2)


def test_int8_kernel_vs_oracle(rng):
    B, S, Hq, Hkv, D = 2, 100, 8, 4, 64
    q = _mk(rng, B, Hq, D)
    k, v = _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    kq, ks = ops.quantize_kv(k)
    vq, vs = ops.quantize_kv(v)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([50, 99], jnp.int32)
    o1 = ops.decode_attention_int8(q, kq, ks, vq, vs, pos, lengths,
                                   use_kernel="pallas", block_s=32)
    o2 = R.decode_attention_int8_ref(q, kq, ks, vq, vs, pos, lengths)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_int8_quantization_error_bounded(rng):
    """§5.2: int8-KV attention must stay close to the fp32 result."""
    B, S, Hq, Hkv, D = 2, 64, 4, 4, 32
    q = _mk(rng, B, Hq, D)
    k, v = _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    kq, ks = ops.quantize_kv(k)
    vq, vs = ops.quantize_kv(v)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.full((B,), S - 1, jnp.int32)
    o_q = R.decode_attention_int8_ref(q, kq, ks, vq, vs, pos, lengths)
    o_f = R.decode_attention_ref(q, k, v, pos, lengths)
    # symmetric per-vector int8: relative error ~1/127
    assert float(jnp.abs(o_q - o_f).max()) < 0.05


def test_quantize_roundtrip(rng):
    x = _mk(rng, 4, 7, 16)
    q, s = ops.quantize_kv(x)
    x2 = ops.dequantize_kv(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(x2, x, atol=float(jnp.abs(x).max()) / 100)


# ---------------------------------------------------------------------------
# speculative-decode verify kernel: T queries per row in one KV sweep
# ---------------------------------------------------------------------------
def _verify_tables(rng, b, mp, page, lengths, t, num_pages):
    """Contiguous-prefix tables covering lengths[b] + t tokens per row."""
    tables = np.full((b, mp), -1, np.int32)
    perm = list(rng.permutation(num_pages))
    for row in range(b):
        for k in range(-(-(int(lengths[row]) + t) // page)):
            tables[row, k] = perm.pop()
    return jnp.asarray(tables)


@pytest.mark.parametrize("t", [1, 3, 5])
@pytest.mark.parametrize("kw", FEATS)
def test_paged_verify_kernel_vs_oracle(t, kw, rng):
    b, hkv, g, dh, page, mp = 3, 2, 3, 8, 4, 8
    num_pages = b * mp
    lengths = jnp.asarray([0, 7, 13], jnp.int32)
    pk = _mk(rng, num_pages, page, hkv, dh)
    pv = _mk(rng, num_pages, page, hkv, dh)
    q = _mk(rng, b, t, hkv * g, dh)
    tables = _verify_tables(rng, b, mp, page, np.asarray(lengths), t,
                            num_pages)
    o1 = ops.paged_verify_attention(q, pk, pv, tables, lengths,
                                    use_kernel="pallas", **kw)
    o2 = R.paged_verify_attention_ref(q, pk, pv, tables, lengths, **kw)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_paged_verify_t1_matches_decode_kernel(rng):
    """k = 0 speculative decode degenerates to vanilla decode: the T == 1
    verify pass must agree with the single-token decode kernel."""
    b, hkv, g, dh, page, mp = 2, 2, 2, 16, 4, 6
    num_pages = b * mp
    lengths = jnp.asarray([5, 11], jnp.int32)
    pk = _mk(rng, num_pages, page, hkv, dh)
    pv = _mk(rng, num_pages, page, hkv, dh)
    q = _mk(rng, b, hkv * g, dh)
    tables = _verify_tables(rng, b, mp, page, np.asarray(lengths), 1,
                            num_pages)
    o_dec = ops.paged_decode_attention(q, pk, pv, tables, lengths,
                                       use_kernel="pallas")
    o_ver = ops.paged_verify_attention(q[:, None], pk, pv, tables, lengths,
                                       use_kernel="pallas")[:, 0]
    np.testing.assert_allclose(o_ver, o_dec, atol=3e-6)


def test_paged_verify_kernel_unmapped_row_is_zero(rng):
    b, t, hkv, g, dh, page, mp = 2, 3, 1, 2, 8, 4, 3
    pk = _mk(rng, 6, page, hkv, dh)
    pv = _mk(rng, 6, page, hkv, dh)
    q = _mk(rng, b, t, hkv * g, dh)
    tables = jnp.asarray([[0, 1, -1], [-1, -1, -1]], jnp.int32)
    lengths = jnp.asarray([4, 99], jnp.int32)
    for use in ("ref", "pallas"):
        o = ops.paged_verify_attention(q, pk, pv, tables, lengths,
                                       use_kernel=use)
        assert float(jnp.abs(o[1]).max()) == 0.0
        assert float(jnp.abs(o[0]).max()) > 0.0


def test_verify_refs_match_per_position_decode(rng):
    """Row-by-row oracle: position t of the verify output equals a decode
    call with lengths + t, for dense fp and int8 storage."""
    b, s, t, hq, hkv, dh = 2, 24, 3, 4, 2, 16
    q = _mk(rng, b, t, hq, dh)
    k, v = _mk(rng, b, s, hkv, dh), _mk(rng, b, s, hkv, dh)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    lengths = jnp.asarray([9, 17], jnp.int32)
    o = ops.verify_attention(q, k, v, pos, lengths)
    kq, ks = ops.quantize_kv(k)
    vq, vs = ops.quantize_kv(v)
    o8 = ops.verify_attention_int8(q, kq, ks, vq, vs, pos, lengths)
    for j in range(t):
        d = R.decode_attention_ref(q[:, j], k, v, pos, lengths + j)
        np.testing.assert_allclose(o[:, j], d, atol=3e-6)
        d8 = R.decode_attention_int8_ref(q[:, j], kq, ks, vq, vs, pos,
                                         lengths + j)
        np.testing.assert_allclose(o8[:, j], d8, atol=3e-6)


def test_kernel_matches_model_decode_attention(rng, key):
    """kernel == layers.flash_attention == what the model executes."""
    from repro.models import layers as L
    B, S, Hq, Hkv, D = 2, 40, 4, 2, 32
    q, k, v = _mk(rng, B, Hq, D), _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    lengths = jnp.asarray([20, 39], jnp.int32)
    o_kernel = ops.decode_attention(q, k, v, pos, lengths,
                                    use_kernel="pallas", block_s=16)
    o_model = L.flash_attention(q[:, None], k, v, lengths[:, None], pos,
                                causal=True, kv_chunk=64)[:, 0]
    np.testing.assert_allclose(o_kernel, o_model, atol=3e-5)
