"""Layer-level unit tests: chunked flash attention vs naive, masks,
GQA grouping, norms, rope, convs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _mk(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=9),
    dict(causal=True, window=9, sink=2),
    dict(causal=True, softcap=5.0),
])
@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (4, 1)])
def test_flash_matches_naive(rng, kw, hq, hkv):
    B, Sq, Sk, D = 2, 37, 53, 16
    q, k, v = _mk(rng, B, Sq, hq, D), _mk(rng, B, Sk, hkv, D), _mk(rng, B, Sk, hkv, D)
    qpos = jnp.broadcast_to(jnp.arange(16, 16 + Sq), (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    kpos = kpos.at[0, 40:].set(-1)  # invalid cache slots
    o1 = L.flash_attention(q, k, v, qpos, kpos, q_chunk=8, kv_chunk=8, **kw)
    o2 = L.naive_attention(q, k, v, qpos, kpos, **kw)
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_flash_fully_masked_rows_are_zero(rng):
    B, Sq, Sk, H, D = 1, 4, 8, 2, 8
    q, k, v = _mk(rng, B, Sq, H, D), _mk(rng, B, Sk, H, D), _mk(rng, B, Sk, H, D)
    qpos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kpos = jnp.full((B, Sk), -1)  # nothing valid
    o = L.flash_attention(q, k, v, qpos, kpos, kv_chunk=4)
    np.testing.assert_allclose(o, 0.0, atol=1e-7)


def test_flash_gqa_equals_repeated_kv(rng):
    """GQA must equal MHA with kv heads repeated."""
    B, S, Hq, Hkv, D = 2, 16, 4, 2, 8
    q = _mk(rng, B, S, Hq, D)
    k, v = _mk(rng, B, S, Hkv, D), _mk(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    o1 = L.flash_attention(q, k, v, pos, pos, kv_chunk=8)
    krep = jnp.repeat(k, Hq // Hkv, axis=2)
    vrep = jnp.repeat(v, Hq // Hkv, axis=2)
    o2 = L.flash_attention(q, krep, vrep, pos, pos, kv_chunk=8)
    np.testing.assert_allclose(o1, o2, atol=1e-5)


def test_rope_preserves_norm_and_relativity(rng):
    x = _mk(rng, 1, 5, 2, 16)
    pos = jnp.broadcast_to(jnp.arange(5), (1, 5))
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # q.k depends only on relative distance
    q, k = _mk(rng, 1, 1, 1, 16), _mk(rng, 1, 1, 1, 16)
    def dot_at(pq, pk):
        qq = L.rope(q, jnp.array([[pq]]), 1e4)
        kk = L.rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_rms_norm_unit_scale(rng):
    x = _mk(rng, 4, 32) * 7.0
    y = L.rms_norm(x, jnp.zeros(32))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_causal_conv_streaming(rng):
    B, S, D, CW = 2, 19, 12, 4
    w = _mk(rng, CW, D)
    x = _mk(rng, B, S, D)
    yf, _ = L.causal_conv1d(w, x)
    st = jnp.zeros((B, CW - 1, D))
    ys = []
    for i in range(S):
        yi, st = L.causal_conv1d(w, x[:, i:i + 1], st)
        ys.append(yi)
    np.testing.assert_allclose(yf, jnp.concatenate(ys, 1), atol=1e-5)
