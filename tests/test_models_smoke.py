"""Per-architecture smoke tests (REQUIRED by the brief): a reduced variant
of every assigned architecture runs one forward and one train step on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.config import ASSIGNED_ARCHS, get_arch
from repro.models import model as M
from repro.training.train import make_train_step

B, S = 2, 24


def _inputs(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    enc = None
    if cfg.frontend != "none":
        enc = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq, cfg.encoder_d_model)), jnp.float32)
    return tokens, enc


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_smoke(arch, rng, key):
    cfg = tiny_cfg(arch)
    # reduced: <= 2 layers per brief, rounded up to one full layer-pattern
    # period (the vlm pattern is 5 layers: 4 self-attn + 1 cross-attn)
    assert cfg.num_layers <= max(4, len(cfg.layer_pattern))
    assert cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = M.init_params(key, cfg)
    tokens, enc = _inputs(cfg, rng)
    logits, aux = M.train_forward(params, cfg, tokens, enc_feats=enc,
                                  q_chunk=8, kv_chunk=8)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, rng, key):
    cfg = tiny_cfg(arch)
    params = M.init_params(key, cfg)
    tokens, enc = _inputs(cfg, rng)
    init_state, train_step = make_train_step(cfg, q_chunk=8, kv_chunk=8)
    state = init_state(params)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if enc is not None:
        batch["enc_feats"] = enc
    state2, metrics = jax.jit(train_step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_state_shapes(arch, key):
    cfg = tiny_cfg(arch)
    st = M.init_decode_state(cfg, B, 32)
    assert st["lengths"].shape == (B,)
    # every arch must expose a decode step (serve_step)
    params = M.init_params(key, cfg)
    logits, st = M.decode_step(params, cfg, st,
                               jnp.zeros((B, 1), jnp.int32), kv_chunk=8)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_all_full_configs_registered():
    from repro.core.config import list_archs
    archs = list_archs()
    for a in ASSIGNED_ARCHS:
        assert a in archs
    # paper's own eval models present too
    for a in ("llama-7b", "llama-13b", "opt-175b"):
        assert a in archs


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    spec = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    cfg = get_arch(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)
