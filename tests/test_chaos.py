"""Chaos harness + self-healing serving loop.

A seeded ``FaultPlan`` injects crashes, hangs, compute errors, dropped /
duplicated completions, pool exhaustion, tier I/O failures, and wire
corruption at named sites.  The contract under test: every fault class
either heals token-exact (supervised retry / failover + re-prefill from
token history) or completes with an explicitly *detected* degradation —
never an unhandled exception, never a silently wrong token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (CHAOS_KW, STORAGE_KW, fault_specs, random_spec,
                      serve_trace, tiny_cfg)
from repro.chaos import FaultPlan, FaultSpec, tree_digest
from repro.core.hetero import HeteroPipelineEngine, StepFault
from repro.models import model as M
from repro.serving import paged_cache as PC
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    spec = random_spec(rng, cfg, 6)
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


# ---------------------------------------------------------------------------
# the fault matrix: fault class x storage backend x schedule.  Token
# equality against the colocated oracle IS the recovery proof — the
# equivalence matrix already pins fault-free hetero == colocated.
# ---------------------------------------------------------------------------
MATRIX = [(f, s, "ooo") for f in ("crash", "drop")
          for s in ("dense", "paged", "int8")]
MATRIX += [("crash", "dense", "fifo"), ("drop", "paged", "fifo"),
           ("error", "dense", "ooo"), ("error", "int8", "fifo"),
           ("pool", "paged", "ooo"), ("pool", "paged", "fifo"),
           ("hang", "dense", "ooo"), ("dup", "int8", "ooo")]


@pytest.mark.parametrize("fault,storage,schedule", MATRIX)
def test_fault_matrix_token_exact(setup, fault, storage, schedule):
    cfg, params, spec, oracle = setup
    plan = FaultPlan(fault_specs(fault))
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, schedule=schedule, chaos=plan,
                      **STORAGE_KW[storage], **CHAOS_KW)
    assert plan.count() >= 1, "fault never fired — the matrix is vacuous"
    assert got == oracle


def test_chaos_off_is_inert(setup):
    """An empty plan must behave exactly like chaos=None."""
    cfg, params, spec, oracle = setup
    plan = FaultPlan()
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, chaos=plan, **CHAOS_KW)
    assert got == oracle and plan.count() == 0


def test_mixed_fault_plan_acceptance(setup):
    """The acceptance scenario: one seeded plan mixing worker crash,
    completion drop, tier-I/O failure, stored-payload corruption, and
    pool exhaustion over a full tiered serving run — every request
    finishes token-exact."""
    cfg, params, spec, oracle = setup
    kw = dict(backend="hetero", num_r_workers=2, paged_kv=True,
              page_size=4, kv_tiering=True, preempt_after=2,
              cache_len=32)
    oracle_t = serve_trace(params, cfg, spec, **kw)
    assert oracle_t == oracle
    plan = FaultPlan([
        FaultSpec(site="r_step", kind="crash", wid=1, after=40),
        FaultSpec(site="completion", kind="drop", after=15),
        FaultSpec(site="tier_put", times=2),
        FaultSpec(site="tier_corrupt", times=1),
        FaultSpec(site="pool", after=30),
    ], seed=3)
    got = serve_trace(params, cfg, spec, chaos=plan, **kw, **CHAOS_KW)
    assert got == oracle
    assert plan.count("r_step") >= 1 and plan.count("completion") >= 1
    assert plan.count("tier_put") >= 1 and plan.count("tier_corrupt") >= 1


# ---------------------------------------------------------------------------
# supervisor bookkeeping: metrics, fault events, lifecycle marks
# ---------------------------------------------------------------------------
def test_supervisor_metrics_and_fault_events(setup):
    cfg, params, spec, oracle = setup
    plan = FaultPlan(fault_specs("drop"))
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2, chaos=plan,
                        observability=True, **CHAOS_KW)
    try:
        for i, (p, n, _) in enumerate(spec):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        eng.run(max_steps=400)
        got = {r.rid: list(r.generated) for r in eng.finished}
        assert got == oracle
        m = eng.metrics()
        assert m["fault_count"] >= 1 and m["recovered_count"] >= 1
        kinds = [ev["kind"] for ev in eng.fault_events]
        assert "CollectTimeout" in kinds and "recovered" in kinds
        # the engine counted the dropped completion's retry, not a
        # failover: no worker was removed
        assert len(eng.engine.workers) == 2
        # lifecycle marks: some request lived through the fault
        marked = [r for r in eng.finished
                  if any(e[0] == "fault" for e in r.events)]
        assert marked and all(
            any(e[0] == "recovered" for e in r.events) for r in marked)
    finally:
        eng.close()


def test_unhealable_fault_reraises_with_rids(setup):
    """Satellite: with the retry budget at zero the StepFault surfaces,
    and its message names the affected request ids — not just
    worker/layer coordinates."""
    cfg, params, spec, oracle = setup
    plan = FaultPlan([FaultSpec(site="r_step", kind="crash", wid=0,
                                after=40)])
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2, chaos=plan,
                        max_step_retries=0, **CHAOS_KW)
    try:
        for i, (p, n, _) in enumerate(spec):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        with pytest.raises(StepFault,
                           match=r"in-flight rids: \[\d") as ei:
            eng.run(max_steps=400)
        assert ei.value.dead_wids == (0,)
        assert eng.faults >= 1 and eng.recoveries == 0
    finally:
        eng.close()


def test_close_warns_on_hung_worker():
    """Satellite: close() must not silently leak a thread that failed
    to join — it warns with the stuck worker ids."""
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    plan = FaultPlan([FaultSpec(site="r_step", kind="hang", wid=0,
                                hang_s=8.0)])
    eng = HeteroPipelineEngine(params, cfg, batch=4, cache_len=16,
                               num_r_workers=2, num_microbatches=2,
                               collect_timeout_s=0.5, chaos=plan)
    eng.load_prefill(0, jnp.ones((2, 4), jnp.int32), jnp.full((2,), 4))
    eng.load_prefill(1, jnp.ones((2, 4), jnp.int32), jnp.full((2,), 4))
    with pytest.raises(RuntimeError, match="timed out"):
        eng.decode_step([jnp.ones((2, 1), jnp.int32)] * 2)
    with pytest.warns(RuntimeWarning, match=r"\[0\] did not exit"):
        eng.close()


def test_dup_completion_counted_not_fatal(setup):
    cfg, params, spec, oracle = setup
    plan = FaultPlan(fault_specs("dup"))
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2, chaos=plan,
                        **CHAOS_KW)
    try:
        for i, (p, n, _) in enumerate(spec):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        eng.run(max_steps=400)
        got = {r.rid: list(r.generated) for r in eng.finished}
        assert got == oracle
        # the dup was absorbed by the idempotent scatter and counted;
        # token equality above proves it never corrupted a step
        assert eng.engine.step_stats.get("dup_completion_count", 0) >= 1
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# fleet integration: snapshot / migration-wire corruption
# ---------------------------------------------------------------------------
def test_snapshot_corruption_degrades_to_exact_reprefill(setup):
    """A corrupted KV snapshot fails its checksum at restore; the
    manager refuses it and re-prefills from token history instead —
    still token-exact, with the corruption recorded in telemetry."""
    from repro.fleet import FleetManager, WorkerProfile
    cfg, params, spec, oracle = setup

    def mk_fleet():
        return FleetManager([WorkerProfile(name="a"),
                             WorkerProfile(name="b")],
                            snapshot_interval=2, recovery="snapshot")

    # times=-1: EVERY snapshot capture corrupts its first layer —
    # later clean captures must not paper over the fault
    plan = FaultPlan([FaultSpec(site="wire_corrupt", where="snapshot",
                                times=-1),
                      FaultSpec(site="r_step", kind="crash", wid=0,
                                after=40)])
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2,
                        fleet=mk_fleet(), chaos=plan, **CHAOS_KW)
    try:
        for i, (p, n, _) in enumerate(spec):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        eng.run(max_steps=400)
        got = {r.rid: list(r.generated) for r in eng.finished}
        assert got == oracle
        assert plan.count("wire_corrupt") >= 1
        events = eng.fleet.telemetry.events_of("corruption")
        assert events and events[0].detail["source"] == "snapshot"
        rec = eng.fleet.telemetry.events_of("recovery")
        assert rec and rec[-1].detail["mode"] == "reprefill"
    finally:
        eng.close()


def test_migration_wire_corruption_detected_and_replayed(setup):
    """wire_corrupt(where='migration'): the repartition drops the
    payload that fails its transport checksum, installs zeros, and the
    manager replays those rows from token history — tokens stay
    oracle-exact and the corruption is attributed in telemetry."""
    from repro.fleet import FleetManager, WorkerProfile
    cfg, params, spec, oracle = setup
    plan = FaultPlan([FaultSpec(site="wire_corrupt", where="migration")])
    fleet = FleetManager([WorkerProfile(name="a"),
                          WorkerProfile(name="b")])
    eng = ServingEngine(params, cfg, batch=4, cache_len=48,
                        backend="hetero", num_r_workers=2, fleet=fleet,
                        chaos=plan, **CHAOS_KW)
    try:
        for i, (p, n, _) in enumerate(spec):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        for _ in range(6):
            eng.step()
        fleet.rebalance_now([(0, 2), (2, 2)])     # forced migration
        eng.run(max_steps=400)
        got = {r.rid: list(r.generated) for r in eng.finished}
        assert got == oracle
        assert plan.count("wire_corrupt") >= 1
        events = fleet.telemetry.events_of("corruption")
        assert events and events[0].detail["source"] == "migration-wire"
        assert events[0].detail["replayed"] >= 1
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# satellite: tier swap failure must never lose a page from both homes
# ---------------------------------------------------------------------------
PAGE, MAXP = 4, 5


def _conserved(a):
    free, cached, parked = set(a.free), set(a.prefix.lru), set(a.parked)
    assert len(free) + len(cached) + len(parked) + a.used_pages() \
        == a.num_pages
    return free, cached, parked


def test_tier_put_failure_conserves_pages():
    """A tier write failure mid-eviction reclaims the page anyway: it
    must not vanish from both the device pool and the tier."""
    plan = FaultPlan([FaultSpec(site="tier_put")])
    tier = PC.HostTier(chaos=plan)
    a = PC.PagedAllocator(2, 8, PAGE, MAXP, tier=tier)
    a.pool_reader = lambda: {0: {"k": np.zeros((8, PAGE), np.float32)}}
    a.admit(0, 16)
    a.admit(1, 16)                       # pool exactly full
    assert a.park_row(1, np.arange(16, dtype=np.int32))
    got = a._take_page()                 # evicts parked; tier.put fails
    assert tier.stats["put_failed"] == 1
    assert tier.swapped_pages() == 0     # nothing made it to the tier
    # conservation: the taken page is already refcounted to the caller
    free, cached, parked = _conserved(a)
    assert got not in free | cached | parked


def test_tier_restore_failure_keeps_pool_consistent():
    """A tier read failure mid-restore frees the staging page and keeps
    the tier entry; a corrupted payload is detected by its checksum.
    Neither crashes the probe."""
    base = np.arange(16, dtype=np.int32)
    for fault, stat in [("tier_get", "get_failed"),
                        ("tier_corrupt", "corrupt")]:
        tier = PC.HostTier()
        a = PC.PagedAllocator(2, 8, PAGE, MAXP, tier=tier)
        a.pool_reader = lambda: {0: {"k": np.zeros((8, PAGE),
                                                   np.float32)}}
        if fault == "tier_corrupt":      # corrupt on the way IN
            tier.chaos = FaultPlan([FaultSpec(site="tier_corrupt")])
        a.admit(0, 16)
        assert a.park_row(0, base)
        assert a.swap_out_all_parked() == 4
        if fault == "tier_get":          # fail on the way OUT
            tier.chaos = FaultPlan([FaultSpec(site="tier_get")])
        b = PC.PagedAllocator(2, 8, PAGE, MAXP, tier=tier)
        ids, cached = b.probe_prefix(base, restore=True)   # no raise
        assert tier.stats[stat] >= 1, fault
        _conserved(b)


# ---------------------------------------------------------------------------
# chaos plan / checksum units
# ---------------------------------------------------------------------------
def test_fault_plan_is_deterministic():
    def run(seed):
        p = FaultPlan([FaultSpec(site="r_step", after=2, times=2)],
                      seed=seed)
        fired = [p.fire("r_step", wid=0) is not None for _ in range(8)]
        arr = np.arange(16, dtype=np.float32)
        FaultPlan(seed=seed).corrupt_array(arr)
        return fired, arr.tobytes()
    f1, c1 = run(1)
    assert f1 == [False, False, True, True, False, False, False, False]
    assert (f1, c1) == run(1)
    assert c1 != run(2)[1]               # seed changes the corruption
    assert c1 != np.arange(16, dtype=np.float32).tobytes()


def test_fault_spec_filters_and_records():
    p = FaultPlan([FaultSpec(site="r_step", kind="crash", wid=1)])
    assert p.fire("r_step", wid=0) is None       # wrong worker
    assert p.fire("completion", wid=1) is None   # wrong site
    spec = p.fire("r_step", wid=1, layer=2)
    assert spec is not None and spec.kind == "crash"
    assert p.fire("r_step", wid=1) is None       # times=1 exhausted
    assert p.count() == 1 and p.fired[0]["layer"] == 2


def test_tree_digest_detects_bit_flips():
    t = {"k": np.arange(8, dtype=np.float32),
         "v": [np.ones(3, np.float32), None]}
    d = tree_digest(t)
    assert d == tree_digest(dict(reversed(list(t.items()))))
    assert d != tree_digest({"k": t["k"], "v": [np.ones(3, np.float32),
                                                np.zeros(1)]})
    t["k"][3] += 1.0
    assert d != tree_digest(t)
    # dtype and shape are part of the digest, not just the bytes
    z32 = np.zeros(4, np.float32)
    assert tree_digest(z32) != tree_digest(np.zeros(8, np.float16))
    assert tree_digest(z32) != tree_digest(z32.reshape(2, 2))


def test_spec_verify_fault_heals_token_exact(setup):
    """The speculative-decode verify step is a chaos site ("verify"):
    an abort AFTER the candidate KV append but BEFORE commit must heal
    token-exactly — the supervisor re-prefills every live row from
    token history (discarding the orphaned candidate appends) and the
    re-queued verify step commits the same tokens, because drafts are
    deterministic given the drafter state and the sampling RNG is only
    consumed at commit."""
    from repro.serving.engine import SpecConfig
    cfg, params, spec, oracle = setup
    plan = FaultPlan([FaultSpec(site="verify", after=3, times=2)])
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, spec_decode=SpecConfig(k=3),
                      paged_kv=True, page_size=4, chaos=plan, **CHAOS_KW)
    assert plan.count("verify") == 2, "verify fault never fired"
    assert got == oracle
