"""The runtime lock-order sanitizer: a deliberately inverted
acquisition order across two threads must be witnessed and fatal,
reentrant re-entry and consistent orders must stay clean, and the
env-flag gate must keep production locks plain stdlib objects."""
import threading
import time

import pytest

from repro.analysis.lockwitness import (LockWitness, WitnessedLock,
                                        make_lock)


def _locks(w, *names, reentrant=False):
    return [make_lock(n, reentrant=reentrant, witness=w) for n in names]


def test_inverted_order_across_threads_detected():
    w = LockWitness()
    a, b = _locks(w, "Sink._lock", "Tier._lock")
    # rendezvous so both threads really interleave rather than one
    # finishing before the other starts
    t1_has_a = threading.Event()
    t2_has_b = threading.Event()

    def t1():
        with a:
            t1_has_a.set()
            t2_has_b.wait(5)
            # don't nest for real (that could deadlock) — release and
            # take B afterwards holding nothing; the A->B edge below
            # comes from t3
        with b:
            pass

    def t3():
        with a:
            with b:                      # A -> B
                pass

    def t2():
        t1_has_a.wait(5)
        with b:
            t2_has_b.set()
            with a:                      # B -> A: the inversion
                pass

    th3 = threading.Thread(target=t3)
    th3.start(); th3.join()
    th1 = threading.Thread(target=t1)
    th2 = threading.Thread(target=t2)
    th1.start(); th2.start()
    th1.join(10); th2.join(10)

    inv = w.inversions()
    assert ("Sink._lock", "Tier._lock") in inv
    with pytest.raises(AssertionError, match="inversion"):
        w.assert_clean()


def test_consistent_order_is_clean():
    w = LockWitness()
    a, b = _locks(w, "Sink._lock", "Tier._lock")

    def worker():
        for _ in range(20):
            with a:
                with b:
                    pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert w.inversions() == []
    w.assert_clean()                     # no raise
    rep = w.report()
    assert {"from": "Sink._lock", "to": "Tier._lock", "count": 80} \
        in rep["edges"]
    assert rep["holds"]["Sink._lock"]["count"] == 80


def test_reentrant_reentry_records_one_hold_no_self_edge():
    w = LockWitness()
    (lk,) = _locks(w, "HostTier._lock", reentrant=True)
    with lk:
        with lk:                         # re-entry, same instance
            pass
    assert w.inversions() == []
    assert w.holds["HostTier._lock"][0] == 1
    assert w.edges == {}


def test_two_instances_same_name_is_self_edge():
    w = LockWitness()
    a, b = _locks(w, "HostTier._lock", "HostTier._lock")
    with a:
        with b:                          # distinct instances, one name
            pass
    assert ("HostTier._lock", "HostTier._lock") in w.inversions()


def test_hold_time_outlier_recorded_not_fatal():
    w = LockWitness()
    w.hold_threshold_s = 0.01
    (lk,) = _locks(w, "SpanTracer._lock")
    with lk:
        time.sleep(0.03)
    rep = w.report()
    assert len(rep["hold_outliers"]) == 1
    out = rep["hold_outliers"][0]
    assert out["lock"] == "SpanTracer._lock" and out["held_s"] > 0.01
    w.assert_clean()                     # outliers are not fatal


def test_reset_clears_state():
    w = LockWitness()
    a, b = _locks(w, "A._lock", "B._lock")
    with a:
        with b:
            pass
    assert w.edges and w.holds
    w.reset()
    assert not w.edges and not w.holds and not w.hold_outliers


def test_make_lock_plain_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_WITNESS", raising=False)
    lk = make_lock("X._lock")
    assert not isinstance(lk, WitnessedLock)
    rk = make_lock("X._lock", reentrant=True)
    with rk:
        with rk:                         # really reentrant
            pass


def test_make_lock_witnessed_under_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_WITNESS", "1")
    lk = make_lock("X._lock")
    assert isinstance(lk, WitnessedLock)


def test_nonblocking_acquire_failure_records_nothing():
    w = LockWitness()
    (lk,) = _locks(w, "A._lock")
    lk.acquire()
    try:
        got = []
        t = threading.Thread(
            target=lambda: got.append(lk.acquire(blocking=False)))
        t.start(); t.join()
        assert got == [False]
    finally:
        lk.release()
    assert w.holds["A._lock"][0] == 1    # only the successful one
