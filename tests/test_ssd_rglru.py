"""SSM / recurrent mixer equivalences (the R-Part of non-attention archs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models import layers as L


def _mk(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("s", [5, 16, 23])
def test_ssd_chunked_matches_naive(rng, chunk, s):
    Bb, H, P, N = 2, 3, 8, 4
    x, dt = _mk(rng, Bb, s, H, P), jax.nn.softplus(_mk(rng, Bb, s, H))
    A_log, B, C, D = _mk(rng, H), _mk(rng, Bb, s, N), _mk(rng, Bb, s, N), _mk(rng, H)
    h0 = _mk(rng, Bb, H, P, N)
    y1, h1 = L.ssd_chunked(x, dt, A_log, B, C, D, chunk=chunk, h0=h0,
                           return_state=True)
    y2, h2 = L.ssd_naive(x, dt, A_log, B, C, D, h0=h0)
    np.testing.assert_allclose(y1, y2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(h1, h2, rtol=3e-4, atol=3e-4)


def test_ssd_step_continues_chunked(rng):
    """Running chunked over s tokens then one step == chunked over s+1."""
    Bb, s, H, P, N = 1, 12, 2, 4, 4
    x, dt = _mk(rng, Bb, s + 1, H, P), jax.nn.softplus(_mk(rng, Bb, s + 1, H))
    A_log, B, C, D = _mk(rng, H), _mk(rng, Bb, s + 1, N), _mk(rng, Bb, s + 1, N), _mk(rng, H)
    y_all, h_all = L.ssd_chunked(x, dt, A_log, B, C, D, chunk=4,
                                 return_state=True)
    _, h_s = L.ssd_chunked(x[:, :s], dt[:, :s], A_log, B[:, :s], C[:, :s],
                           D, chunk=4, return_state=True)
    y_step, h_step = L.ssd_step(x[:, s], dt[:, s], A_log, B[:, s], C[:, s],
                                D, h_s)
    np.testing.assert_allclose(y_step, y_all[:, s], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(h_step, h_all, rtol=3e-4, atol=3e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(2, 8))
def test_ssd_decay_bounded(s, n):
    """Property: with bounded inputs the SSD state norm stays bounded
    (A is negative => contraction)."""
    rng = np.random.default_rng(s * 31 + n)
    Bb, H, P = 1, 2, 4
    x = jnp.asarray(rng.standard_normal((Bb, s, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((Bb, s, H)), jnp.float32))
    A_log = jnp.zeros(H)  # A = -1
    B = jnp.asarray(rng.standard_normal((Bb, s, n)), jnp.float32) * 0.1
    C = jnp.asarray(rng.standard_normal((Bb, s, n)), jnp.float32)
    D = jnp.zeros(H)
    _, h = L.ssd_chunked(x, dt, A_log, B, C, D, chunk=8, return_state=True)
    assert np.isfinite(np.asarray(h)).all()
    assert float(jnp.abs(h).max()) < 100.0


def test_rglru_scan_matches_step_loop(rng):
    Bb, S, W = 2, 17, 12
    p = {"w_a": _mk(rng, W, W, scale=0.3), "b_a": _mk(rng, W),
         "w_x": _mk(rng, W, W, scale=0.3), "b_x": _mk(rng, W),
         "lam": _mk(rng, W)}
    xc = _mk(rng, Bb, S, W)
    hs = L.rglru_scan(p, xc)
    h = jnp.zeros((Bb, W))
    outs = []
    for i in range(S):
        o, h = L.rglru_step(p, xc[:, i], h)
        outs.append(o)
    np.testing.assert_allclose(hs, jnp.stack(outs, 1), rtol=1e-4, atol=1e-5)


def test_rglru_stability(rng):
    """|a_t| < 1 always: long sequences cannot blow up."""
    Bb, S, W = 1, 200, 8
    p = {"w_a": _mk(rng, W, W), "b_a": _mk(rng, W),
         "w_x": _mk(rng, W, W), "b_x": _mk(rng, W), "lam": _mk(rng, W)}
    xc = _mk(rng, Bb, S, W)
    hs = L.rglru_scan(p, xc)
    assert np.isfinite(np.asarray(hs)).all()
