"""The RA0xx checkers: each fails on its bad fixture, passes on its
clean one, and honours ``# noqa`` suppressions; the CLI runs strict-
clean on the real tree (the merge gate)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (ALL_CHECKERS, ChaosSiteCrossCheck,
                            JitPurity, LockDiscipline,
                            MetricsKeySchema, Project,
                            SimTimeDiscipline, SuppressionHygiene,
                            run_checks)

REPO = Path(__file__).resolve().parents[1]


def project_from(tmp_path, src: dict, ref: dict = None):
    """Build a throwaway Project from {relpath: source} dicts."""
    sroot = tmp_path / "src"
    for rel, text in src.items():
        p = sroot / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    rroot = tmp_path / "tests"
    rroot.mkdir(exist_ok=True)
    for rel, text in (ref or {}).items():
        (rroot / rel).write_text(textwrap.dedent(text))
    return Project(tmp_path, [sroot], [rroot])


def run_one(checker_cls, project):
    report = run_checks(project, [checker_cls()])
    return [f for f in report["findings"] if f.check == checker_cls.code]


# ---------------------------------------------------------------------------
# RA001 — lock discipline
# ---------------------------------------------------------------------------
BAD_LOCKS = """
    import threading

    class Sink:
        def __init__(self):
            self._lock = threading.Lock()
            self.bufs = {}
        def deliver(self, tier, k, v):
            with self._lock:
                self.bufs[k] = v
                tier.stash(k, v)       # acquires Tier._lock under ours
        def fast_path(self, k, v):
            self.bufs[k] = v           # guarded attr, no lock held

    class Tier:
        def __init__(self):
            self._lock = threading.Lock()
        def stash(self, k, v):
            with self._lock:
                pass
        def drain(self, sink, k):
            with self._lock:
                sink.deliver(None, k, 0)   # inverse order -> cycle
"""

CLEAN_LOCKS = """
    import threading

    class Sink:
        def __init__(self):
            self._lock = threading.Lock()
            self.bufs = {}
        def deliver(self, k, v):
            with self._lock:
                self.bufs[k] = v
        def snapshot(self):
            with self._lock:
                return dict(self.bufs)
"""


def test_ra001_bad_fixture(tmp_path):
    found = run_one(LockDiscipline,
                    project_from(tmp_path, {"locks.py": BAD_LOCKS}))
    msgs = " | ".join(f.message for f in found)
    assert "cycle" in msgs, msgs
    assert "without holding" in msgs          # lock-free guarded mutation


def test_ra001_clean_fixture(tmp_path):
    assert run_one(LockDiscipline,
                   project_from(tmp_path, {"locks.py": CLEAN_LOCKS})) == []


def test_ra001_lock_graph_artifact(tmp_path):
    ch = LockDiscipline()
    ch.run(project_from(tmp_path, {"locks.py": BAD_LOCKS}))
    g = ch.artifacts["lock_graph"]
    assert "Sink._lock" in g["nodes"] and "Tier._lock" in g["nodes"]
    pairs = {(e["from"], e["to"]) for e in g["edges"]}
    assert ("Sink._lock", "Tier._lock") in pairs
    assert ("Tier._lock", "Sink._lock") in pairs


# ---------------------------------------------------------------------------
# RA002 — jit purity
# ---------------------------------------------------------------------------
BAD_JIT = """
    import time
    import jax
    import numpy as np

    seen = []

    def make_step():
        def f(x, state):
            t = time.perf_counter()     # wall clock under trace
            seen.append(t)              # closure mutation
            s = float(x)                # concretize traced operand
            return x + np.asarray(x) + s
        return jax.jit(f)

    def hot(xs):
        return [jax.jit(lambda a: a + 1)(x) for x in xs]
"""

CLEAN_JIT = """
    import jax
    import jax.numpy as jnp

    def make_step():
        def f(x, state):
            scale = x.shape[0]          # static metadata is fine
            return jnp.tanh(x) * scale + state
        return jax.jit(f)

    _cache = {}
    def cached(shape):
        if shape not in _cache:
            _cache[shape] = jax.jit(lambda a: a * 2)
        return _cache[shape]
"""


def test_ra002_bad_fixture(tmp_path):
    found = run_one(JitPurity,
                    project_from(tmp_path, {"jit.py": BAD_JIT}))
    msgs = " | ".join(f.message for f in found)
    assert "time.perf_counter" in msgs
    assert "closed-over" in msgs
    assert "float()" in msgs
    assert "np.asarray" in msgs
    assert "defeats the jit cache" in msgs or "fresh jit cache" in msgs


def test_ra002_clean_fixture(tmp_path):
    assert run_one(JitPurity,
                   project_from(tmp_path, {"jit.py": CLEAN_JIT})) == []


# ---------------------------------------------------------------------------
# RA003 — sim-time discipline
# ---------------------------------------------------------------------------
BAD_SIM = """
    import time

    class Tier:
        def __init__(self):
            self.stats = {"sim_seconds": 0.0}
        def put(self, nbytes, bw):
            time.sleep(nbytes / bw)          # wall clock in sim domain
            self.stats["sim_seconds"] += nbytes / bw
"""

CLEAN_SIM = """
    import time

    class Tier:
        def __init__(self):
            self.stats = {"sim_seconds": 0.0}
        def put(self, nbytes, bw):
            self.stats["sim_seconds"] += nbytes / bw

    class WallClockWorker:                   # not sim-domain: fine
        def step(self):
            time.sleep(0.001)
"""


def test_ra003_bad_fixture(tmp_path):
    found = run_one(SimTimeDiscipline,
                    project_from(tmp_path, {"sim.py": BAD_SIM}))
    assert len(found) == 1 and "time.sleep" in found[0].message


def test_ra003_clean_fixture(tmp_path):
    assert run_one(SimTimeDiscipline,
                   project_from(tmp_path, {"sim.py": CLEAN_SIM})) == []


# ---------------------------------------------------------------------------
# RA004 — chaos-site cross-check
# ---------------------------------------------------------------------------
REGISTRY = """
    FAULT_SITES = (
        "r_step",
        "ghost_site",
    )
"""

BAD_CHAOS = {
    "repro/chaos/plan.py": REGISTRY,
    "engine.py": """
        def step(plan):
            plan.fire("r_stpe")        # typo'd site
    """,
}
BAD_CHAOS_REF = {
    "test_x.py": """
        def test_r_step():
            assert "r_step"
    """,
}

CLEAN_CHAOS = {
    "repro/chaos/plan.py": """
        FAULT_SITES = (
            "r_step",
        )
    """,
    "engine.py": """
        def step(plan):
            plan.fire("r_step")
    """,
}


def test_ra004_bad_fixture(tmp_path):
    found = run_one(ChaosSiteCrossCheck,
                    project_from(tmp_path, BAD_CHAOS, BAD_CHAOS_REF))
    msgs = " | ".join(f.message for f in found)
    assert "'r_stpe' is not in FAULT_SITES" in msgs
    assert "'ghost_site' has no fire() injection point" in msgs
    assert "'ghost_site' is never referenced by any test" in msgs
    # r_step HAS an injection point but its only caller is the typo'd
    # one, so it keeps its test ref and loses its injection
    assert "'r_step' has no fire() injection point" in msgs


def test_ra004_clean_fixture(tmp_path):
    found = run_one(ChaosSiteCrossCheck,
                    project_from(tmp_path, CLEAN_CHAOS, BAD_CHAOS_REF))
    assert found == []


# ---------------------------------------------------------------------------
# RA005 — metrics-key schema
# ---------------------------------------------------------------------------
BAD_KEYS = """
    class W:
        def __init__(self, registry):
            self.stats = {"throughput": 0.0}      # no unit suffix
            registry.counter("decode_latency")    # no unit suffix
        def bump(self):
            self.stats["queue_depth"] = 1         # no unit suffix
"""

CLEAN_KEYS = """
    class W:
        def __init__(self, registry):
            self.stats = {"throughput_rate": 0.0,
                          "hits": 0}              # legacy alias: ok
            registry.counter("decode_latency_s")
        def bump(self):
            self.stats["queue_depth_count"] = 1
"""


def test_ra005_bad_fixture(tmp_path):
    found = run_one(MetricsKeySchema,
                    project_from(tmp_path, {"w.py": BAD_KEYS}))
    keys = {f.message.split("'")[1] for f in found}
    assert keys == {"throughput", "decode_latency", "queue_depth"}


def test_ra005_clean_fixture(tmp_path):
    assert run_one(MetricsKeySchema,
                   project_from(tmp_path, {"w.py": CLEAN_KEYS})) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
SUPPRESSED_SIM = """
    import time

    class Tier:
        def __init__(self):
            self.stats = {"sim_seconds": 0.0}
        def put(self, nbytes, bw):
            time.sleep(0)  # noqa: RA003 - deliberate yield, not a model path
            self.stats["sim_seconds"] += nbytes / bw
"""

BARE_SUPPRESSED_SIM = SUPPRESSED_SIM.replace(
    "# noqa: RA003 - deliberate yield, not a model path", "# noqa")


def test_noqa_suppresses_finding(tmp_path):
    project = project_from(tmp_path, {"sim.py": SUPPRESSED_SIM})
    report = run_checks(project, [SimTimeDiscipline()])
    assert report["findings"] == []
    assert len(report["suppressed"]) == 1
    assert report["suppressed"][0].check == "RA003"


def test_bare_noqa_flagged_by_hygiene(tmp_path):
    project = project_from(tmp_path, {"sim.py": BARE_SUPPRESSED_SIM})
    report = run_checks(project,
                        [SimTimeDiscipline(), SuppressionHygiene()])
    # the RA003 finding is muted, but RA000 flags the bare noqa itself
    checks = {f.check for f in report["findings"]}
    assert checks == {"RA000"}


def test_unjustified_code_suppression_flagged(tmp_path):
    text = SUPPRESSED_SIM.replace(
        "# noqa: RA003 - deliberate yield, not a model path",
        "# noqa: RA003")
    report = run_checks(project_from(tmp_path, {"sim.py": text}),
                        [SimTimeDiscipline(), SuppressionHygiene()])
    msgs = " | ".join(f.message for f in report["findings"])
    assert "no justification" in msgs


def test_wrong_code_does_not_suppress(tmp_path):
    text = SUPPRESSED_SIM.replace("RA003", "RA001")
    report = run_checks(project_from(tmp_path, {"sim.py": text}),
                        [SimTimeDiscipline()])
    assert len(report["findings"]) == 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd or REPO, env=env)


def test_cli_strict_clean_on_real_tree():
    """The merge gate: the suite runs clean on this repository."""
    r = _cli("--strict")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_json_report_and_strict_exit(tmp_path):
    bad = tmp_path / "src"
    bad.mkdir()
    (bad / "w.py").write_text(textwrap.dedent(BAD_KEYS))
    out = tmp_path / "findings.json"
    r = _cli("--root", str(tmp_path), str(bad), "--ref", str(bad),
             "--select", "RA005", "--strict", "--json", str(out))
    assert r.returncode == 1
    payload = json.loads(out.read_text())
    assert payload["strict"] is True
    assert {f["check"] for f in payload["findings"]} == {"RA005"}


def test_cli_select_and_disable(tmp_path):
    bad = tmp_path / "src"
    bad.mkdir()
    (bad / "w.py").write_text(textwrap.dedent(BAD_KEYS))
    # RA004 is disabled too: this throwaway tree has no chaos registry
    r = _cli("--root", str(tmp_path), str(bad), "--ref", str(bad),
             "--disable", "RA004,RA005", "--strict")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_list():
    r = _cli("--list")
    for code in ("RA001", "RA002", "RA003", "RA004", "RA005", "RA000"):
        assert code in r.stdout


def test_all_checkers_registered():
    assert [c.code for c in ALL_CHECKERS] == \
        ["RA001", "RA002", "RA003", "RA004", "RA005"]


@pytest.mark.parametrize("cls", ALL_CHECKERS)
def test_checkers_have_metadata(cls):
    assert cls.code.startswith("RA") and cls.name and cls.describe
