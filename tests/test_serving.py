"""Serving engine integration: continuous batching, admission policies,
backend equivalence, load control."""
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
import jax


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=64, vocab=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(rng, n, vmax=128, maxnew=6):
    return [Request(rid=i,
                    prompt=rng.integers(1, vmax,
                                        size=rng.integers(3, 9)).astype(np.int32),
                    max_new_tokens=maxnew) for i in range(n)]


@pytest.mark.parametrize("adm", ["greedy", "sls", "loadctl"])
def test_all_requests_complete(setup, rng, adm):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch=4, cache_len=32,
                        admission=adm, target_len=12, interval=4)
    for r in _reqs(rng, 7):
        eng.submit(r)
    done = eng.run(max_steps=300)
    assert len(done) == 7
    assert all(len(r.generated) == 6 for r in done)


def test_hetero_backend_equals_colocated(setup, rng):
    cfg, params = setup
    prompt = np.arange(1, 6, dtype=np.int32)
    outs = []
    for backend in ("colocated", "hetero"):
        # batch 2 / 2 micro-batches = 1 row per micro-batch, so at most
        # one R-worker (more than mb_size rows is now a hard error
        # instead of a silently dropped empty slice)
        eng = ServingEngine(params, cfg, batch=2, cache_len=32,
                            backend=backend, num_r_workers=1,
                            num_microbatches=2, kv_chunk=8)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        done = eng.run(max_steps=100)
        outs.append(done[0].generated)
        eng.close()
    assert outs[0] == outs[1]


def test_continuous_batching_isolation(setup, rng):
    """A request's tokens must not depend on co-scheduled requests
    (cache row replacement must not leak state)."""
    cfg, params = setup
    prompt = np.asarray([3, 14, 15, 92, 6], np.int32)
    solo = ServingEngine(params, cfg, batch=4, cache_len=32)
    solo.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    ref = solo.run(max_steps=100)[0].generated

    busy = ServingEngine(params, cfg, batch=4, cache_len=32)
    for i, r in enumerate(_reqs(rng, 6)):
        busy.submit(r)
    busy.submit(Request(rid=99, prompt=prompt, max_new_tokens=5))
    done = busy.run(max_steps=300)
    target = [r for r in done if r.rid == 99][0]
    assert target.generated == ref


def test_loadctl_bounds_resident_length(setup, rng):
    cfg, params = setup
    w_lim = 60
    eng = ServingEngine(params, cfg, batch=8, cache_len=32,
                        admission="loadctl", target_len=11, interval=2,
                        w_lim=w_lim)
    for r in _reqs(rng, 24, maxnew=5):
        eng.submit(r)
    eng.run(max_steps=400)
    peak = max(rec.resident_len for rec in eng.records)
    assert peak <= w_lim + 16   # slack: ragged prompt lengths vs S estimate


def test_eos_stops_generation(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch=2, cache_len=64)
    eng.submit(Request(rid=0, prompt=np.asarray([5, 6], np.int32),
                       max_new_tokens=40, eos_token=None))
    done = eng.run(max_steps=200)
    assert len(done[0].generated) == 40


def test_engine_from_plan(setup):
    """§4.3 integration: the perf model sizes the engine (eq. 7-11)."""
    cfg, params = setup
    eng = ServingEngine.from_plan(params, cfg, seq_len=32, max_batch=8,
                                  backend="colocated")
    assert eng.batch >= 2 and eng.batch <= 8
    assert eng.plan["workers"] >= 1
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=4))
    done = eng.run(max_steps=50)
    assert len(done) == 1 and len(done[0].generated) == 4
