"""Serving engine integration: continuous batching, admission policies,
backend equivalence, load control."""
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
import jax


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-3-8b", layers=2, d_model=64, vocab=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(rng, n, vmax=128, maxnew=6):
    return [Request(rid=i,
                    prompt=rng.integers(1, vmax,
                                        size=rng.integers(3, 9)).astype(np.int32),
                    max_new_tokens=maxnew) for i in range(n)]


@pytest.mark.parametrize("adm", ["greedy", "sls", "loadctl"])
def test_all_requests_complete(setup, rng, adm):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch=4, cache_len=32,
                        admission=adm, target_len=12, interval=4)
    for r in _reqs(rng, 7):
        eng.submit(r)
    done = eng.run(max_steps=300)
    assert len(done) == 7
    assert all(len(r.generated) == 6 for r in done)


def test_hetero_backend_equals_colocated(setup, rng):
    cfg, params = setup
    prompt = np.arange(1, 6, dtype=np.int32)
    outs = []
    for backend in ("colocated", "hetero"):
        # batch 2 / 2 micro-batches = 1 row per micro-batch, so at most
        # one R-worker (more than mb_size rows is now a hard error
        # instead of a silently dropped empty slice)
        eng = ServingEngine(params, cfg, batch=2, cache_len=32,
                            backend=backend, num_r_workers=1,
                            num_microbatches=2, kv_chunk=8)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        done = eng.run(max_steps=100)
        outs.append(done[0].generated)
        eng.close()
    assert outs[0] == outs[1]


def test_continuous_batching_isolation(setup, rng):
    """A request's tokens must not depend on co-scheduled requests
    (cache row replacement must not leak state)."""
    cfg, params = setup
    prompt = np.asarray([3, 14, 15, 92, 6], np.int32)
    solo = ServingEngine(params, cfg, batch=4, cache_len=32)
    solo.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    ref = solo.run(max_steps=100)[0].generated

    busy = ServingEngine(params, cfg, batch=4, cache_len=32)
    for i, r in enumerate(_reqs(rng, 6)):
        busy.submit(r)
    busy.submit(Request(rid=99, prompt=prompt, max_new_tokens=5))
    done = busy.run(max_steps=300)
    target = [r for r in done if r.rid == 99][0]
    assert target.generated == ref


def test_loadctl_bounds_resident_length(setup, rng):
    cfg, params = setup
    w_lim = 60
    eng = ServingEngine(params, cfg, batch=8, cache_len=32,
                        admission="loadctl", target_len=11, interval=2,
                        w_lim=w_lim)
    for r in _reqs(rng, 24, maxnew=5):
        eng.submit(r)
    eng.run(max_steps=400)
    peak = max(rec.resident_len for rec in eng.records)
    assert peak <= w_lim + 16   # slack: ragged prompt lengths vs S estimate


def test_eos_stops_generation(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, batch=2, cache_len=64)
    eng.submit(Request(rid=0, prompt=np.asarray([5, 6], np.int32),
                       max_new_tokens=40, eos_token=None))
    done = eng.run(max_steps=200)
    assert len(done[0].generated) == 40


def test_engine_from_plan(setup):
    """§4.3 integration: the perf model sizes the engine (eq. 7-11)."""
    cfg, params = setup
    eng = ServingEngine.from_plan(params, cfg, seq_len=32, max_batch=8,
                                  backend="colocated")
    assert eng.batch >= 2 and eng.batch <= 8
    assert eng.plan["workers"] >= 1
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=4))
    done = eng.run(max_steps=50)
    assert len(done) == 1 and len(done[0].generated) == 4


# ---------------------------------------------------------------------------
# admission length cap: one rule, one message, exact boundary
# ---------------------------------------------------------------------------
CAPPED_KW = {
    "paged": dict(backend="hetero", num_r_workers=1, paged_kv=True,
                  page_size=4),
    "chunked": dict(backend="hetero", num_r_workers=1, prefill_chunk=4),
    "spec": dict(backend="hetero", num_r_workers=1),
}


@pytest.mark.parametrize("mode", sorted(CAPPED_KW))
def test_length_cap_boundary_and_unified_message(setup, mode):
    """Every configuration that cannot let the ring wrap (paged KV,
    chunked prefill, speculative decoding) must admit a request sized
    EXACTLY to cache_len — prompt + max_new_tokens == cache_len — and
    reject one token more with the single unified message (the two old
    copies of this guard had drifted, giving different messages for the
    same impossibility)."""
    from repro.serving.engine import SpecConfig
    cfg, params = setup
    kw = dict(CAPPED_KW[mode])
    if mode == "spec":
        kw["spec_decode"] = SpecConfig(k=2)
    cache_len = 16
    eng = ServingEngine(params, cfg, batch=2, cache_len=cache_len,
                        num_microbatches=2, **kw)
    try:
        prompt = np.arange(1, 9, dtype=np.int32)          # 8 tokens
        fits = Request(rid=0, prompt=prompt,
                       max_new_tokens=cache_len - len(prompt))
        eng.submit(fits)                                   # == cap: fine
        with pytest.raises(ValueError) as ei:
            eng.submit(Request(rid=1, prompt=prompt,
                               max_new_tokens=cache_len - len(prompt) + 1))
        msg = str(ei.value)
        assert f"exceeds cache_len ({cache_len})" in msg
        assert "prompt (8)" in msg and "—" in msg          # reason attached
        done = eng.run(max_steps=120)
        assert [r.rid for r in done] == [0]
        assert len(done[0].generated) == cache_len - len(prompt)
        assert done[0].finish_reason == "length"
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# finish accounting: exactly one record, one reason — both orderings of
# "stop token" vs "max_new_tokens cap" at the same step
# ---------------------------------------------------------------------------
def _probe_unique_tail(params, cfg, prompt, n=8):
    """Serve greedily once and pick an index whose token first appears
    there, so eos-at-that-index stops exactly at the cap."""
    eng = ServingEngine(params, cfg, batch=2, cache_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n))
    g = eng.run(max_steps=100)[0].generated
    for i in range(len(g) - 1, -1, -1):
        if g[i] not in g[:i]:
            return g, i
    pytest.skip("trace has no first-occurrence token to pin")


@pytest.mark.parametrize("spec_k", [0, 2])
def test_stop_at_cap_records_once_with_stop_reason(setup, spec_k):
    """A stop token landing exactly on the max_new_tokens-th token must
    finish the request ONCE with finish_reason == "stop" (token
    semantics outrank budget exhaustion); the same budget without a
    stop token finishes with "length".  Regression: the engine's three
    finish sites used to do their own bookkeeping — a cap+stop
    coincidence depended on which site saw it first."""
    from repro.serving.engine import SpecConfig
    cfg, params = setup
    prompt = np.asarray([7, 3, 11, 19], np.int32)
    g, i = _probe_unique_tail(params, cfg, prompt)
    kw = dict(backend="hetero", num_r_workers=1, num_microbatches=2) \
        if spec_k else {}
    if spec_k:
        kw["spec_decode"] = SpecConfig(k=spec_k)
    eng = ServingEngine(params, cfg, batch=2, cache_len=64, **kw)
    try:
        # ordering 1: stop token arrives exactly at the cap
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=i + 1,
                           eos_token=g[i]))
        # ordering 2: cap reached, no stop token anywhere
        eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=i + 1))
        done = eng.run(max_steps=150)
        by = {r.rid: r for r in done}
        assert sorted(by) == [0, 1]
        assert [r.rid for r in done].count(0) == 1      # recorded once
        assert by[0].generated == g[:i + 1] == by[1].generated
        assert by[0].finish_reason == "stop"
        assert by[1].finish_reason == "length"
        assert by[0].status.name == "DONE" and by[1].status.name == "DONE"
    finally:
        if eng.backend == "hetero":
            eng.close()
