"""Property-based suite for the ref-counted copy-on-write PagedAllocator.

Random ``admit`` / ``append_chunk`` / decode-grow / ``release`` /
CoW-``adopt_prefix`` / spec-rejection-``truncate`` / migration sequences
must preserve, after EVERY op:

  * refcount conservation — sum of refcounts == mapped table slots;
  * no double-free — the free list holds unique ids, disjoint from both
    mapped pages and the refcount-zero cached (LRU) set;
  * pool partition — free + cached + used == num_pages, with
    used == #pages at refcount > 0;
  * contiguous-table-prefix layout per row;
  * capacity coherence — an active unfrozen row maps exactly
    ceil(min(len, cap)/page) pages;
  * released non-shared pages are write-clean (checked against a real
    device pool in the deterministic test below).

The hypothesis path (``tests/_hyp.py`` shim) runs 1000 examples when
hypothesis is installed (CI); the deterministic fallback fuzz below it
always runs, so the invariants are exercised even without hypothesis.
Prompts are drawn from a tiny family pool so prefix-cache probes
actually collide and CoW/adoption paths fire constantly.
"""
import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.serving import paged_cache as PC

ROWS, PAGES, PAGE, MAXP = 4, 24, 4, 5
CAP = MAXP * PAGE

# three prompt families sharing pairwise prefixes of different depths,
# longer than a row can hold so any admitted length has a valid prefix
_BASE = np.arange(1, 2 * CAP + 1, dtype=np.int32)
FAMILIES = [
    _BASE,
    np.concatenate([_BASE[:8], 1000 + _BASE[8:]]),    # shares 2 pages
    np.concatenate([_BASE[:14], 2000 + _BASE[14:]]),  # shares 3.5 pages
]


class Harness:
    """Drives a PagedAllocator through the op vocabulary while keeping
    the ground truth needed for the invariants (per-row family/length)."""

    def __init__(self, prefix_cache=True):
        self.a = PC.PagedAllocator(ROWS, PAGES, PAGE, MAXP,
                                   prefix_cache=prefix_cache)
        self.fam = [None] * ROWS        # family index of each row

    # -- ops ---------------------------------------------------------------
    def admit(self, row, fam, length):
        try:
            self.a.admit(row, length)
        except MemoryError:
            self.fam[row] = None
            return
        self.fam[row] = fam if length else None
        if length:
            self.a.register_prefix(row, FAMILIES[fam][:length])

    def release(self, row):
        self.a.release(row)
        self.fam[row] = None

    def decode_grow(self, mask):
        new = np.minimum(self.a.lengths + 1, CAP + 3)  # may exceed cap
        self.a.ensure_lengths(new, mask=np.asarray(mask, bool))
        self.a.take_clones()

    def append_chunk(self, row, cnt):
        base = np.zeros((ROWS,), np.int64)
        counts = np.zeros((ROWS,), np.int64)
        base[row] = int(self.a.lengths[row])
        counts[row] = cnt
        if base[row] == 0 and self.fam[row] is None:
            self.fam[row] = 0           # fresh chunked admission
        if base[row] + cnt > CAP:
            return                      # keep chunk fuzz inside capacity
        self.a.append_chunk(base, counts)
        self.a.take_clones()

    def adopt(self, row, fam, want):
        """Prefix-cache admission: probe family ``fam``'s prompt of
        length ``want`` and adopt the clamped cached prefix (the
        serving engine's rule: at least the last token is recomputed)."""
        tokens = FAMILIES[fam][:want]
        ids, cached = self.a.probe_prefix(tokens)
        eff = min(cached, want - 1)
        if eff <= 0:
            return
        ids = ids[:-(-eff // PAGE)]
        self.a.adopt_prefix(row, ids, eff)
        self.fam[row] = fam
        # stream the suffix like the chunk path would (triggers the
        # partial-page CoW when eff is not page-aligned)
        base = np.zeros((ROWS,), np.int64)
        counts = np.zeros((ROWS,), np.int64)
        base[row], counts[row] = eff, want - eff
        self.a.append_chunk(base, counts)
        self.a.take_clones()
        self.a.register_prefix(row, tokens)

    def truncate(self, row, new_len):
        """Spec-decode rejection: roll an active row back to a shorter
        length — the dropped pages must rejoin the pool (or the LRU,
        for cached prefix pages) without breaking any invariant."""
        cur = int(self.a.lengths[row])
        if not self.a.active[row] or cur <= 1:
            return
        self.a.truncate(row, 1 + new_len % cur)

    def migrate(self):
        """Reassign-and-reinstall: what a fleet topology change does —
        every surviving row re-admitted privately (sharing and the
        index drop with the old allocator), then re-registered."""
        lens = [int(self.a.lengths[r]) if self.a.active[r] else 0
                for r in range(ROWS)]
        fams = list(self.fam)
        fresh = PC.PagedAllocator(ROWS, PAGES, PAGE, MAXP,
                                  prefix_cache=self.a.prefix is not None)
        self.a = fresh
        for r in range(ROWS):
            if lens[r]:
                self.admit(r, fams[r] if fams[r] is not None else 0,
                           min(lens[r], CAP))
            else:
                self.fam[r] = None

    # -- invariants --------------------------------------------------------
    def check(self):
        a = self.a
        tables = a.tables
        mapped_ids = tables[tables >= 0]
        # refcount conservation
        assert int(a.refcount.sum()) == len(mapped_ids)
        assert (a.refcount >= 0).all()
        # per-page refcount == number of slots mapping it
        uniq, counts = np.unique(mapped_ids, return_counts=True)
        for pid, c in zip(uniq, counts):
            assert a.refcount[pid] == c
        # no double free; free/cached/mapped disjoint
        free = set(a.free)
        assert len(free) == len(a.free)
        cached = set(a.prefix.lru) if a.prefix is not None else set()
        assert not (free & set(int(i) for i in mapped_ids))
        assert not (free & cached)
        assert not (cached & set(int(i) for i in mapped_ids))
        # partition of the pool
        assert len(free) + len(cached) + a.used_pages() == PAGES
        assert a.used_pages() == int((a.refcount > 0).sum())
        # per-row layout
        for r in range(ROWS):
            m = tables[r] >= 0
            n = int(m.sum())
            assert m[:n].all(), "mapped slots must form a prefix"
            if not a.active[r]:
                assert n == 0 and a.lengths[r] == 0
            elif not a.frozen[r]:
                assert n == -(-min(int(a.lengths[r]), CAP) // PAGE)
            else:
                assert n <= -(-min(int(a.lengths[r]), CAP) // PAGE)


def _run_ops(ops, prefix_cache=True):
    h = Harness(prefix_cache)
    for op in ops:
        kind = op[0] % 7
        row = op[1] % ROWS
        fam = op[2] % len(FAMILIES)
        length = 1 + op[3] % CAP
        if kind == 0:
            h.admit(row, fam, length)
        elif kind == 1:
            h.release(row)
        elif kind == 2:
            h.decode_grow([bool((op[3] >> i) & 1) for i in range(ROWS)])
        elif kind == 3:
            h.append_chunk(row, 1 + op[3] % (2 * PAGE))
        elif kind == 4:
            h.adopt(row, fam, length)
        elif kind == 5:
            h.truncate(row, op[3])
        else:
            h.migrate()
        h.check()
    return h


_op = st.tuples(st.integers(0, 6), st.integers(0, ROWS - 1),
                st.integers(0, 2), st.integers(0, CAP - 1))


@settings(max_examples=1000, deadline=None)
@given(st.lists(_op, min_size=1, max_size=30))
def test_allocator_properties_hypothesis(ops):
    _run_ops(ops)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("prefix_cache", [True, False])
def test_allocator_properties_fallback_fuzz(seed, prefix_cache):
    """Deterministic twin of the hypothesis property (always runs, even
    without hypothesis installed): 6 seeds x 250 random ops."""
    rng = np.random.default_rng(1234 + seed)
    ops = [tuple(int(x) for x in rng.integers(0, 2 ** 16, 4))
           for _ in range(250)]
    _run_ops(ops, prefix_cache)


def test_hypothesis_shim_consistent():
    """The hypothesis path must actually run in CI (where hypothesis is
    installed); here it may be a skip — both are fine, but the shim's
    flag must match what the import produced."""
    import _hyp
    assert hasattr(_hyp, "HAVE_HYPOTHESIS")
    assert _hyp.HAVE_HYPOTHESIS is HAVE_HYPOTHESIS


# ---------------------------------------------------------------------------
# write-cleanliness of released non-shared pages, against a REAL pool
# ---------------------------------------------------------------------------
def test_released_nonshared_pages_write_clean(rng):
    """Drive a device pool alongside the allocator: after releasing a
    non-shared row, its freed pages' bytes must stay bit-identical
    through other rows' appends and CoW clones (freed != writable)."""
    a = PC.PagedAllocator(3, 12, PAGE, MAXP, prefix_cache=True)
    pool = PC.init_page_pool(12, PAGE, 2, 8)

    def write(row, pos):
        k = np.asarray(rng.standard_normal((3, 2, 8)), np.float32)
        lengths = np.full((3,), -1)
        lengths[row] = pos
        active = np.zeros((3,), bool)
        active[row] = True
        return PC.write_token_paged(pool, a.tables_device(),
                                    np.asarray(lengths), k, k,
                                    active=active)

    a.admit(0, 6)
    a.register_prefix(0, FAMILIES[0][:6])
    for p in range(6):
        pool = write(0, p)
    a.admit(1, 5)
    for p in range(5):
        pool = write(1, p)
    # row 2 adopts row 0's prefix INCLUDING the partial tail page
    # (5 of 6 tokens -> 2 pages shared, the second half-full), so its
    # first append below lands inside a shared page and must CoW
    ids, cached = a.probe_prefix(FAMILIES[0][:6])
    assert cached == 6
    a.adopt_prefix(2, ids[:2], 5)
    # release the NON-shared row 1: its pages are free now
    freed = sorted(int(i) for i in a.tables[1][a.tables[1] >= 0])
    a.release(1)
    assert set(freed) <= set(a.free)
    snap = {k: np.array(v)[freed] for k, v in pool.items()}
    # decode-append rows 0 and 2 (row 2's append CoW-clones the shared
    # page; the clone must come from the free list, then drop from it)
    a.ensure_lengths(np.asarray([7, 0, 6]),
                     mask=np.asarray([True, False, True]))
    clones = a.take_clones()
    assert clones, "append into a shared page must CoW"
    pool = PC.clone_pool_pages(pool, clones)
    pool = write(0, 6)
    pool = write(2, 5)
    still_free = [p for p in freed if p in a.free]
    for k in pool:
        got = np.array(pool[k])[still_free]
        want = snap[k][[freed.index(p) for p in still_free]]
        assert np.array_equal(got, want), f"freed page bytes changed ({k})"
    # refcount conservation at the end, for good measure
    assert int(a.refcount.sum()) == int((a.tables >= 0).sum())
