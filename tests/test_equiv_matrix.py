"""THE serving-equivalence matrix, consolidated.

One parameterized harness replaces the dense/paged/int8 x ooo/fifo x
chunked/monolithic equivalence checks that used to be copy-pasted across
``test_hetero.py`` / ``test_paged_hetero.py`` / ``test_prefill_chunked.py``:
every combination serves the same randomized continuous-arrival trace and
must reproduce the colocated whole-prompt oracle's generated tokens
EXACTLY (greedy).  The shared-prefix dimension rides the same harness:
two requests sharing a page-aligned prefix (served with
``prefix_cache=True``) must decode bit-identically to two independent
requests — i.e. to the same oracle that never shares anything.
"""
import jax
import numpy as np
import pytest

from conftest import STORAGE_KW, random_spec, serve_trace, tiny_cfg
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(42)
    spec = random_spec(rng, cfg, 6)
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


# storage x prefill on the default OoO schedule, plus FIFO spot checks —
# the consolidated matrix (FIFO==OoO equivalence at the engine level is
# separately pinned by test_hetero.test_fifo_schedule_matches_ooo)
MATRIX = [(s, p, "ooo") for s in STORAGE_KW for p in ("mono", "chunk")]
MATRIX += [("dense", "mono", "fifo"), ("paged", "chunk", "fifo")]


@pytest.mark.parametrize("storage,prefill,schedule", MATRIX)
def test_serving_matrix_matches_colocated(setup, storage, prefill,
                                          schedule):
    cfg, params, spec, oracle = setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, schedule=schedule,
                      prefill_chunk=5 if prefill == "chunk" else 0,
                      **STORAGE_KW[storage])
    assert got == oracle


# ---------------------------------------------------------------------------
# the speculative-decoding dimension: speculation must be invisible too
# ---------------------------------------------------------------------------
SPEC_MATRIX = [(s, "ooo") for s in STORAGE_KW]
SPEC_MATRIX += [("dense", "fifo"), ("paged", "fifo")]


@pytest.mark.parametrize("storage,schedule", SPEC_MATRIX)
def test_spec_decode_greedy_matches_colocated(setup, storage, schedule):
    """Greedy serving with self-speculation on (draft k tokens on the
    S-resident drafter, verify all candidates in one chunk, commit via
    the deterministic accept walk, truncate the rejected KV) must
    reproduce the non-speculative colocated oracle BIT-EXACTLY — the
    tentpole invariant: speculation changes the schedule, never the
    tokens."""
    from repro.serving.engine import SpecConfig
    cfg, params, spec, oracle = setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, schedule=schedule,
                      spec_decode=SpecConfig(k=3), **STORAGE_KW[storage])
    assert got == oracle


def test_spec_decode_composes_with_chunked_prefill(setup):
    """Verify works and prefill chunks legally share one chunk-only
    pipelined step (same micro-batch, disjoint rows) — tokens must
    still match the oracle."""
    from repro.serving.engine import SpecConfig
    cfg, params, spec, oracle = setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, prefill_chunk=5,
                      spec_decode=SpecConfig(k=2), **STORAGE_KW["paged"])
    assert got == oracle


# ---------------------------------------------------------------------------
# the shared-prefix dimension: sharing must be invisible to the tokens
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def prefix_setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)  # 2 pages
    spec = [
        (np.concatenate([shared,
                         rng.integers(1, cfg.vocab_size, 5).astype(np.int32)]),
         5, 0),
        (np.concatenate([shared,
                         rng.integers(1, cfg.vocab_size, 3).astype(np.int32)]),
         5, 2),                       # arrives later -> adopts the prefix
        (rng.integers(1, cfg.vocab_size, 7).astype(np.int32), 4, 3),
    ]
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


# ---------------------------------------------------------------------------
# the park/restore dimension: leaving residency must be invisible too
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def park_setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(21)
    # rid 0 is the victim: long generation so it is provably mid-flight
    # at the preemption step on every backend/schedule combination
    spec = [
        (rng.integers(1, cfg.vocab_size, 9).astype(np.int32), 10, 0),
        (rng.integers(1, cfg.vocab_size, 6).astype(np.int32), 5, 1),
        (rng.integers(1, cfg.vocab_size, 12).astype(np.int32), 6, 2),
        (rng.integers(1, cfg.vocab_size, 5).astype(np.int32), 4, 4),
    ]
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


PARK_MATRIX = [(s, sched) for s in ("dense", "paged", "int8")
               for sched in ("ooo", "fifo")]
PARK_MATRIX += [("paged-int8", "ooo")]


@pytest.mark.parametrize("storage,schedule", PARK_MATRIX)
def test_parked_and_restored_matches_uninterrupted(park_setup, storage,
                                                   schedule):
    """A request preempted mid-conversation and later resumed must emit
    the exact tokens of one that never left residency.  On paged
    storage with tiering the victim's KV is parked (and restorable via
    the tier) and readmission adopts it back; dense/int8 fall back to
    drop-and-replay — both paths must be token-invisible."""
    cfg, params, spec, oracle = park_setup
    kw = dict(STORAGE_KW[storage])
    if kw.get("paged_kv"):
        kw["kv_tiering"] = True
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, schedule=schedule,
                      preempt_at={3: [0]}, **kw)
    assert got == oracle


@pytest.mark.parametrize("storage", ["paged", "paged-int8"])
@pytest.mark.parametrize("prefill", ["mono", "chunk"])
def test_shared_prefix_decodes_like_independent(prefix_setup, storage,
                                                prefill):
    """Two requests sharing a page-aligned prefix, admitted through the
    prefix cache (refcounted pages + suffix-only prefill), must produce
    the exact tokens of two independent requests — across fp/int8 paged
    storage and monolithic/chunked prefill."""
    cfg, params, spec, oracle = prefix_setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=1, prefix_cache=True,
                      prefill_chunk=4 if prefill == "chunk" else 0,
                      **STORAGE_KW[storage])
    assert got == oracle
