"""THE serving-equivalence matrix, consolidated.

One parameterized harness replaces the dense/paged/int8 x ooo/fifo x
chunked/monolithic equivalence checks that used to be copy-pasted across
``test_hetero.py`` / ``test_paged_hetero.py`` / ``test_prefill_chunked.py``:
every combination serves the same randomized continuous-arrival trace and
must reproduce the colocated whole-prompt oracle's generated tokens
EXACTLY (greedy).  The shared-prefix dimension rides the same harness:
two requests sharing a page-aligned prefix (served with
``prefix_cache=True``) must decode bit-identically to two independent
requests — i.e. to the same oracle that never shares anything.
"""
import jax
import numpy as np
import pytest

from conftest import STORAGE_KW, random_spec, serve_trace, tiny_cfg
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(42)
    spec = random_spec(rng, cfg, 6)
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


# storage x prefill on the default OoO schedule, plus FIFO spot checks —
# the consolidated matrix (FIFO==OoO equivalence at the engine level is
# separately pinned by test_hetero.test_fifo_schedule_matches_ooo)
MATRIX = [(s, p, "ooo") for s in STORAGE_KW for p in ("mono", "chunk")]
MATRIX += [("dense", "mono", "fifo"), ("paged", "chunk", "fifo")]


@pytest.mark.parametrize("storage,prefill,schedule", MATRIX)
def test_serving_matrix_matches_colocated(setup, storage, prefill,
                                          schedule):
    cfg, params, spec, oracle = setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=2, schedule=schedule,
                      prefill_chunk=5 if prefill == "chunk" else 0,
                      **STORAGE_KW[storage])
    assert got == oracle


# ---------------------------------------------------------------------------
# the shared-prefix dimension: sharing must be invisible to the tokens
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def prefix_setup():
    cfg = tiny_cfg("granite-3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)  # 2 pages
    spec = [
        (np.concatenate([shared,
                         rng.integers(1, cfg.vocab_size, 5).astype(np.int32)]),
         5, 0),
        (np.concatenate([shared,
                         rng.integers(1, cfg.vocab_size, 3).astype(np.int32)]),
         5, 2),                       # arrives later -> adopts the prefix
        (rng.integers(1, cfg.vocab_size, 7).astype(np.int32), 4, 3),
    ]
    oracle = serve_trace(params, cfg, spec, backend="colocated")
    assert len(oracle) == len(spec)
    return cfg, params, spec, oracle


@pytest.mark.parametrize("storage", ["paged", "paged-int8"])
@pytest.mark.parametrize("prefill", ["mono", "chunk"])
def test_shared_prefix_decodes_like_independent(prefix_setup, storage,
                                                prefill):
    """Two requests sharing a page-aligned prefix, admitted through the
    prefix cache (refcounted pages + suffix-only prefill), must produce
    the exact tokens of two independent requests — across fp/int8 paged
    storage and monolithic/chunked prefill."""
    cfg, params, spec, oracle = prefix_setup
    got = serve_trace(params, cfg, spec, backend="hetero",
                      num_r_workers=1, prefix_cache=True,
                      prefill_chunk=4 if prefill == "chunk" else 0,
                      **STORAGE_KW[storage])
    assert got == oracle
